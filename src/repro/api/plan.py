"""ExecPlan: HOW to execute a SimSpec — backend, padding, batching, sharding.

Every execution decision that used to be scattered across
`core/reservoir.py`, `core/ensemble.py`, `kernels/ops.py`, and
`serve/reservoir.py` is declared here and resolved exactly once, in
`repro.api.compile_plan`:

  impl       "auto" consults the measured-latency dispatch table
             (in-process + the persisted per-platform JSON from
             kernels/dispatch_table.py), then the platform gate / VMEM
             heuristic — `kernels.ops.choose_impl`. Explicit values:
             "scan" (core (E, N, 3) layout, bit-identical to the legacy
             `drive` math), "ref" (planes-layout jnp oracle), "fused" /
             "tiled" (Pallas TPU kernels), "chunk" (chunk-resident fused
             RK4: the K-tick x hold_steps x 4-stage loop runs as one
             device-side region — a Pallas kernel on TPU that keeps the
             state planes VMEM-resident and streams W once per chunk, a
             single fused XLA region elsewhere).
  ensemble   E: how many reservoir lanes run per dispatch (1 = solo).
  block_n/e  MXU padding granules for the Pallas kernels.
  n_inner    fused-kernel inner steps (None = one hold window per launch).
  mesh       a jax Mesh makes the plan SHARDED: E spans `ensemble_axes`,
             N spans `model_axis`, with PartitionSpecs from
             `distributed.sharding.reservoir_specs`.
  precision  numerical policy for the compute-bound GEMMs (the paper's
             large-N regime is dominated by the dense N x N coupling GEMM
             re-evaluated 4 x hold_steps times per tick):
               None / "highest"  bit-exact default: every op runs in the
                     spec dtype, results identical to plans that predate
                     the field.
               "bf16_coupling"   the coupling GEMM (W^cp @ m^x) consumes
                     bf16 operands and accumulates in f32 (MXU-native on
                     TPU; on sharded plans this also halves the all-gather
                     wire bytes, subsuming gather_dtype=bf16).
               "mixed"           "bf16_coupling" plus the input-field GEMM
                     (W^in u) in bf16. State carry, all elementwise LLG
                     math, and the RK4 stage accumulation stay f32 — only
                     the GEMMs are reduced, so the NARMA-10 NMSE guardrail
                     (within 10% of f32, pinned by tests) holds.
             Reduced precision applies to the planes impls
             (ref/fused/tiled/chunk) and sharded plans; impl="scan" is the
             repo's bit-exact oracle and refuses it. The readout-learning
             recursion (kernels/rls.py) deliberately stays f32 — P's
             conditioning is the one place bf16 noise compounds.
  gather_dtype  reduced-precision coupling path for sharded plans (bf16
             wire + matmul; see core/ensemble.py §Perf C notes). Subsumed
             by `precision` — an explicit gather_dtype still wins, but new
             code should say precision="bf16_coupling" instead.
  chunk_ticks  K: how many input ticks one serving dispatch covers.
             K > 1 turns `CompiledSim.tick_chunk` into a lax.scan over K
             ticks whose per-tick states stay in a device-side buffer and
             reach the host as ONE transfer per chunk — the pipelined
             serving path (`serve.reservoir.ReservoirEngine.run`) overlaps
             host u-block assembly with device execution of the previous
             chunk. K = 1 keeps per-tick serving semantics.
  learn      online readout learning fused into `tick_chunk`'s per-tick
             scan body: "rls" runs one masked batched recursive-least-
             squares update (kernels/rls.py) per tick — per-lane
             (S, S) = (N+1, N+1) inverse-Gram P and (S, n_out) weight
             lanes ride the dispatch alongside the magnetization, zero
             extra host round-trips. "lms" runs normalized least mean
             squares instead: no P block at all, O(S) state and work per
             tick — approximate where RLS is exact, but the per-candidate
             cost the `repro.tune` search lanes want at large S. None
             (default) keeps tick_chunk inference-only (signature and
             results unchanged).
  aot        ahead-of-time compile: `compile_plan` immediately lowers and
             compiles the chunked serving hot path (`lower().compile()`,
             falling back to executing one masked zero chunk where AOT is
             not wired, e.g. sharded plans) instead of deferring XLA work
             to the first dispatch. Pair with `compilation_cache_dir` to
             populate the on-disk cache at spin-up.
  compilation_cache_dir  opt into JAX's persistent compilation cache: the
             XLA executables this plan compiles are spilled to (and read
             back from) this directory, so cold-start survives process
             restarts. First configured directory wins for the process
             (api/cache.enable_persistent_cache); launcher flag
             `--compilation-cache-dir` threads it through serve + fleet.
             Neither field changes numerics or the compiled executable —
             both are excluded from the PlanCache key.
  learn_lam  RLS forgetting factor in (0, 1]. 1.0 (default) weights all
             history equally and converges to batch ridge regression;
             < 1 exponentially forgets, tracking non-stationary targets.
             RLS-only (LMS has no history weighting to forget).
  learn_reg  RLS regularization: P initializes to I / learn_reg, the
             exact analogue of `fit_ridge`'s `reg`. RLS-only.
  learn_mu   LMS step size in (0, 2) — the normalized-LMS stability
             range, input-scale-free because the update divides by
             ||x||^2. LMS-only.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

try:  # jax is a hard dependency of the repo; guard only for doc tooling
    from jax.sharding import Mesh
except Exception:  # pragma: no cover
    Mesh = object  # type: ignore

PLAN_IMPLS = ("auto", "scan", "ref", "fused", "tiled", "chunk")
PLAN_LEARN = (None, "rls", "lms")
PLAN_PRECISIONS = (None, "highest", "bf16_coupling", "mixed")

# Which impls can execute which physics family (SimSpec.topology). The
# coupled-array Pallas kernels (fused/tiled) bake the N x N coupling GEMM
# into every RK stage; the time-multiplexed delay line has no such stage
# GEMM (feedback is once per tick), so those impls cannot express it and
# compile_plan refuses the pairing up front ("auto" resolves around it).
# Mesh plans shard the coupled array's N axis; neither family decomposes
# that way (the delay line is sequential in N, the transient window is a
# readout detail), so families are unsharded — scale them across ensemble
# lanes / engine replicas instead.
FAMILY_IMPLS = {
    "coupled_array": PLAN_IMPLS,
    "time_multiplexed": ("auto", "scan", "ref", "chunk"),
    "array_transient": ("auto", "scan", "ref", "fused", "tiled", "chunk"),
}


def check_plan_supports_topology(plan: "ExecPlan", topology: str) -> None:
    """Refuse plan/physics-family pairings that have no executable mapping.

    Called by compile_plan after spec validation; kept here so the support
    table lives next to PLAN_IMPLS and stays in sync with new impls.
    """
    allowed = FAMILY_IMPLS.get(topology)
    if allowed is None:
        raise ValueError(
            f"unknown topology {topology!r}; expected one of "
            f"{tuple(FAMILY_IMPLS)}"
        )
    if topology == "coupled_array":
        return
    if plan.mesh is not None:
        raise ValueError(
            f"mesh plans shard the coupled array; topology {topology!r} is "
            "unsharded — scale it across ensemble lanes or engine replicas"
        )
    if plan.impl not in allowed:
        raise ValueError(
            f"impl {plan.impl!r} cannot execute topology {topology!r}; "
            f"supported impls: {allowed}"
        )


# ExecPlan knobs `repro.tune` may search over. All are STRUCTURAL: each is
# either a static argument of the jit'd learn workers (learn_lam / learn_mu
# specialize the compiled update) or folded into per-lane init state once at
# admit (learn_reg -> P0) — so candidates with different values group into
# separate compiled engines, like SimSpec.STRUCT_TUNABLE.
PLAN_TUNABLE = ("learn_lam", "learn_reg", "learn_mu")


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    impl: str = "auto"
    ensemble: int = 1
    block_n: Optional[int] = None  # None = kernels' LANE default
    block_e: Optional[int] = None
    n_inner: Optional[int] = None  # None = full hold window per kernel launch
    mesh: Optional[Mesh] = None
    ensemble_axes: Sequence[str] = ("data",)
    model_axis: Optional[str] = "model"
    gather_dtype: Optional[object] = None
    precision: Optional[str] = None  # None/"highest" = bit-exact
    chunk_ticks: int = 1
    learn: Optional[str] = None  # None = inference-only; "rls"/"lms" = online
    learn_lam: float = 1.0  # RLS forgetting factor, (0, 1]
    learn_reg: float = 1e-6  # RLS regularization: P0 = I / learn_reg
    learn_mu: float = 0.5  # NLMS step size, (0, 2)
    interpret: bool = False
    measure: bool = False  # time impl candidates at compile, pin the winner
    aot: bool = False  # lower().compile() the hot path at compile_plan time
    compilation_cache_dir: Optional[str] = None  # JAX persistent cache dir

    def __post_init__(self):
        if self.impl not in PLAN_IMPLS:
            raise ValueError(f"impl must be one of {PLAN_IMPLS}; got {self.impl!r}")
        if self.ensemble < 1:
            raise ValueError(f"ensemble must be >= 1; got {self.ensemble}")
        if self.mesh is not None and self.impl not in ("auto", "scan"):
            raise ValueError(
                "sharded plans integrate in the core layout via shard_map; "
                f"impl must be 'auto' or 'scan' when mesh is set, got {self.impl!r}"
            )
        if isinstance(self.chunk_ticks, bool) or not isinstance(self.chunk_ticks, int):
            raise ValueError(
                f"chunk_ticks must be an int >= 1; got {self.chunk_ticks!r}"
            )
        if self.chunk_ticks < 1:
            raise ValueError(
                f"chunk_ticks must be >= 1; got {self.chunk_ticks}"
            )
        if self.gather_dtype is not None:
            try:
                np.dtype(self.gather_dtype)
            except TypeError:
                raise ValueError(
                    f"gather_dtype must be a dtype (e.g. jnp.bfloat16) or None; "
                    f"got {self.gather_dtype!r}"
                ) from None
        if self.precision not in PLAN_PRECISIONS:
            raise ValueError(
                f"precision must be one of {PLAN_PRECISIONS}; got "
                f"{self.precision!r}"
            )
        if self.reduced_precision and self.impl == "scan" and self.mesh is None:
            raise ValueError(
                "impl='scan' is the bit-exact oracle; reduced precision "
                f"({self.precision!r}) applies to the planes impls "
                "(ref/fused/tiled/chunk) and sharded plans — use "
                "impl='auto' or an explicit planes impl"
            )
        if self.learn not in PLAN_LEARN:
            raise ValueError(
                f"learn must be one of {PLAN_LEARN}; got {self.learn!r}"
            )
        if self.learn == "lms" and self.mesh is not None:
            raise ValueError(
                "learn='lms' is not wired through the sharded (mesh) serving "
                "path yet — its per-lane weight columns would need the "
                "lane-sharded P-free variant of api/sharded's learn plumbing; "
                "use learn='rls' on sharded plans"
            )
        if not isinstance(self.learn_lam, (int, float)) or isinstance(
            self.learn_lam, bool
        ) or not (0.0 < float(self.learn_lam) <= 1.0):
            raise ValueError(
                f"learn_lam (RLS forgetting factor) must be a float in "
                f"(0, 1]; got {self.learn_lam!r}"
            )
        if not isinstance(self.learn_reg, (int, float)) or isinstance(
            self.learn_reg, bool
        ) or not float(self.learn_reg) > 0.0:
            raise ValueError(
                f"learn_reg (RLS regularization; P0 = I / learn_reg) must be "
                f"> 0; got {self.learn_reg!r}"
            )
        if not isinstance(self.learn_mu, (int, float)) or isinstance(
            self.learn_mu, bool
        ) or not (0.0 < float(self.learn_mu) < 2.0):
            raise ValueError(
                f"learn_mu (NLMS step size) must be a float in (0, 2); got "
                f"{self.learn_mu!r}"
            )
        if self.compilation_cache_dir is not None and not isinstance(
            self.compilation_cache_dir, str
        ):
            raise ValueError(
                "compilation_cache_dir must be a directory path string or "
                f"None; got {self.compilation_cache_dir!r}"
            )

    def with_knobs(self, **knobs) -> "ExecPlan":
        """A new plan with named PLAN_TUNABLE knobs applied — the validated
        write path for parameter search (`repro.tune`). Unknown names raise
        with the valid list; values re-run the full __post_init__
        validation (dataclasses.replace)."""
        for name in knobs:
            if name not in PLAN_TUNABLE:
                raise ValueError(
                    f"unknown plan knob {name!r}; tunable plan knobs: "
                    f"{PLAN_TUNABLE}"
                )
        return dataclasses.replace(self, **knobs)

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    @property
    def effective_precision(self) -> Optional[str]:
        """The precision policy with the bit-exact aliases collapsed:
        returns None for both None and "highest"."""
        return None if self.precision == "highest" else self.precision

    @property
    def reduced_precision(self) -> bool:
        return self.effective_precision is not None

    @property
    def effective_gather_dtype(self):
        """The sharded coupling-path wire/matmul dtype after precision
        resolution: an explicit gather_dtype wins (backward compat);
        otherwise reduced-precision plans gather in bf16."""
        if self.gather_dtype is not None:
            return self.gather_dtype
        if self.reduced_precision:
            import jax.numpy as jnp

            return jnp.bfloat16
        return None
