"""compile_plan(SimSpec, ExecPlan) -> CompiledSim: the one execution surface.

All impl dispatch, padding, ensemble batching, and sharding decisions are
made HERE, once, at plan compilation:

  - "auto" impls resolve through `kernels.ops.choose_impl`, which consults
    the measured-latency dispatch table — in-process measurements first,
    then the persisted per-platform JSON (`kernels/dispatch_table.py`,
    seeded from BENCH_serve.json) — before the platform gate / VMEM
    heuristic. `ExecPlan(measure=True)` times the candidates for this
    (N, E) first and pins the winner.
  - mesh plans lower the same physics through shard_map with the
    PartitionSpecs from `distributed.sharding.reservoir_specs`.

The jit-cached entry points on the returned CompiledSim:

  drive(u, m0=None)            solo reservoir over an input series
  drive_batch(U, m0=None)      E lanes over shared or per-lane series
  integrate(n_steps, ...)      free-run (u = 0) ensemble integration
  tick(m, u, lane_mask=None)   ONE hold window for a slot batch — the
                               serving engine's per-tick path
  tick_chunk(m, U, ...)        K hold windows in one dispatch — the chunked
                               serving hot path; with ExecPlan(learn="rls")
                               it also trains per-lane readouts online
                               (targets/learn_state/learn_mask kwargs)

All jit'd workers are module-level, so every CompiledSim for the same
(static-shape, impl) signature shares one compilation.

Numerical contract (pinned by tests/test_api_plan.py and
tests/test_precision_chunk.py): impl="scan" runs the exact op sequence of
the legacy `reservoir.drive` / `ensemble.integrate_ensemble` paths
(bit-identical results); the planes impls ("ref"/"fused"/"tiled"/"chunk")
and sharded plans agree within the kernel test suite's tolerance (on CPU,
"chunk" is bit-identical to "ref"). `ExecPlan.precision` None/"highest"
plans trace the identical graph they did before the field existed;
"bf16_coupling"/"mixed" reduce only the coupling/input GEMMs (f32 state
carry, f32 RK4 accumulation) and are guarded by the NARMA-10 NMSE
tolerance test.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import integrators, sto
from repro.core.constants import STOParams
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels import rls as krls

from repro.api.plan import ExecPlan, check_plan_supports_topology
from repro.api.spec import SimSpec
from repro.api import sharded as _sharded

PLANES_IMPLS = ("ref", "fused", "tiled", "chunk")


# ---------------------------------------------------------------------------
# jit'd workers — core (E, N, 3) layout ("scan" impl; legacy-exact math)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("hold_steps", "tableau_name"))
def _drive_scan(
    params: STOParams,  # scalar leaves
    w_cp: jnp.ndarray,
    w_in: jnp.ndarray,
    m0: jnp.ndarray,  # (N, 3)
    u_seq: jnp.ndarray,  # (T, N_in)
    dt,
    hold_steps: int,
    tableau_name: str = "rk4",
):
    """Solo drive — the op sequence formerly in core/reservoir._drive_scan,
    moved verbatim so the legacy `drive` shim stays bit-exact."""
    tableau = integrators.TABLEAUX[tableau_name]

    def field(m, h_in_x):
        return sto.llg_field(m, params, w_cp, h_in_x)

    step = integrators.make_step(field, tableau)
    dt = jnp.asarray(dt, dtype=m0.dtype)

    def per_sample(m, u_t):
        # Input held piecewise-constant over the hold window (paper: the
        # input signal is a discrete-point series).
        h_in_x = params.a_in * (w_in @ u_t)  # (N,)

        def inner(mi, _):
            return step(mi, dt, h_in_x), None

        m, _ = jax.lax.scan(inner, m, None, length=hold_steps)
        return m, m[..., 0]  # node states: x-components (paper §3.1)

    mT, states = jax.lax.scan(per_sample, m0, u_seq)
    return mT, states  # states: (T, N)


@functools.partial(jax.jit, static_argnames=("hold_steps", "tableau_name"))
def _drive_scan_batch(
    params_e: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,
    w_in: jnp.ndarray,
    m0_e: jnp.ndarray,  # (E, N, 3)
    u_seq_e: jnp.ndarray,  # (T, E, N_in)
    dt,
    hold_steps: int,
    tableau_name: str = "rk4",
):
    """Ensemble drive in the core layout (per-lane params and inputs)."""
    tableau = integrators.TABLEAUX[tableau_name]

    def field(m, h_in_x):
        return sto.llg_field(m, params_e, w_cp, h_in_x)

    step = integrators.make_step(field, tableau)
    dt = jnp.asarray(dt, dtype=m0_e.dtype)

    def per_sample(m, u_t):
        h_in = params_e.a_in * jnp.einsum("ni,ei->en", w_in, u_t)  # (E, N)

        def inner(mi, _):
            return step(mi, dt, h_in), None

        m, _ = jax.lax.scan(inner, m, None, length=hold_steps)
        return m, m[..., 0]

    mT, states = jax.lax.scan(per_sample, m0_e, u_seq_e)
    return mT, states  # (E, N, 3), (T, E, N)


@functools.partial(jax.jit, static_argnames=("hold_steps", "tableau_name"))
def _tick_scan(params_e, w_cp, w_in, m_planes, u, mask, dt, hold_steps,
               tableau_name: str = "rk4"):
    """Advance all E slots one input tick in the core (E, N, 3) layout.

    Takes/returns the slot store's (3, N, E) planes — the layout shuffle
    lives inside the jit so one dispatch covers the whole tick. The
    integration mirrors `_drive_scan`'s per_sample exactly (same field, same
    step, same op order per lane) so scan-impl serving reproduces solo
    drive() results; masked (idle) lanes return unchanged.
    """
    m = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)
    h_in = params_e.a_in * jnp.einsum("ni,ei->en", w_in, u)  # (E, N)

    def field(mm, h):
        return sto.llg_field(mm, params_e, w_cp, h)

    step = integrators.make_step(field, integrators.TABLEAUX[tableau_name])

    def inner(mi, _):
        return step(mi, dt, h_in), None

    m_new, _ = jax.lax.scan(inner, m, None, length=hold_steps)
    m_new = jnp.where(mask[:, None, None], m_new, m)
    return jnp.transpose(m_new, (2, 1, 0)), jnp.transpose(m_new[..., 0])


@functools.partial(jax.jit, static_argnames=("hold_steps", "tableau_name"))
def _tick_chunk_scan(params_e, w_cp, w_in, m_planes, u_block, mask_block, dt,
                     hold_steps, tableau_name: str = "rk4"):
    """Advance all E slots through K input ticks in ONE dispatch (core layout).

    u_block is (K, E, N_in), mask_block (K, E). The per-tick body is exactly
    `_tick_scan`'s (same h_in einsum, same hold-window scan, same masked
    jnp.where) with the layout shuffle hoisted out of the K-loop — transposes
    are pure data movement, so a K-chunk is bit-identical to K sequential
    `_tick_scan` calls. The stacked states live on device until the caller
    transfers them: (K, N, E) states block, one host copy per chunk instead
    of per tick.
    """
    m = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)

    def field(mm, h):
        return sto.llg_field(mm, params_e, w_cp, h)

    step = integrators.make_step(field, integrators.TABLEAUX[tableau_name])

    def per_tick(m_c, tick_in):
        u_t, mask_t = tick_in
        h_in = params_e.a_in * jnp.einsum("ni,ei->en", w_in, u_t)  # (E, N)

        def inner(mi, _):
            return step(mi, dt, h_in), None

        m_new, _ = jax.lax.scan(inner, m_c, None, length=hold_steps)
        m_new = jnp.where(mask_t[:, None, None], m_new, m_c)
        return m_new, jnp.transpose(m_new[..., 0])  # (N, E)

    mT, states = jax.lax.scan(per_tick, m, (u_block, mask_block))
    return jnp.transpose(mT, (2, 1, 0)), states  # (3, N, E), (K, N, E)


def _learn_chunk_tail(states, y_block, lmask_block, p0, w0, lam):
    """Shared learn tail: states block (K, N, E) -> chunked RLS update.

    Builds the (K, E, S) feature block (node states + bias) and applies
    `kernels.rls.rls_chunk` — the whole chunk's sequential gain/weight
    recursion with O(1) full-P passes. Runs inside the workers' jit, so a
    learning chunk is still ONE dispatch with zero extra host round-trips.
    """
    xb = jnp.concatenate(
        [
            jnp.transpose(states, (0, 2, 1)),  # (K, E, N)
            jnp.ones((states.shape[0], states.shape[2], 1), states.dtype),
        ],
        axis=-1,
    )
    return krls.rls_chunk(p0, w0, xb, y_block, lmask_block, lam)


@functools.partial(
    jax.jit, static_argnames=("lam", "hold_steps", "tableau_name")
)
def _tick_chunk_scan_rls(params_e, w_cp, w_in, m_planes, u_block, mask_block,
                         y_block, lmask_block, p0, w0, lam, dt, hold_steps,
                         tableau_name: str = "rk4"):
    """`_tick_chunk_scan` + the chunked RLS readout update, one dispatch
    (ExecPlan.learn="rls", core layout).

    The integration scan is exactly `_tick_chunk_scan`'s — m and the states
    block are bit-identical to the inference-only chunk — and the chunk's
    states then feed `kernels.rls.rls_chunk`: the full K-tick sequential
    RLS gain recursion applied with ~3 full-P traversals per CHUNK (not per
    tick). lmask_block (K, E) gates which lanes learn which ticks (False =
    P/W value-frozen: idle slots, washout ticks, inference-only tenants).
    Returns (m' (3, N, E), states (K, N, E), P', W', preds (K, E, n_out))
    with preds the a-priori (pre-update) per-tick predictions.
    """
    mT, states = _tick_chunk_scan(
        params_e, w_cp, w_in, m_planes, u_block, mask_block, dt, hold_steps,
        tableau_name,
    )
    pT, wT, preds = _learn_chunk_tail(states, y_block, lmask_block, p0, w0, lam)
    return mT, states, pT, wT, preds


def _lms_chunk_tail(states, y_block, lmask_block, w0, mu):
    """Shared LMS learn tail: states block (K, N, E) -> chunked NLMS update.

    Same feature construction as `_learn_chunk_tail` (node states + bias),
    applied through `kernels.rls.lms_chunk` — O(S) per tick, no P block.
    """
    xb = jnp.concatenate(
        [
            jnp.transpose(states, (0, 2, 1)),  # (K, E, N)
            jnp.ones((states.shape[0], states.shape[2], 1), states.dtype),
        ],
        axis=-1,
    )
    return krls.lms_chunk(w0, xb, y_block, lmask_block, mu)


@functools.partial(
    jax.jit, static_argnames=("mu", "hold_steps", "tableau_name")
)
def _tick_chunk_scan_lms(params_e, w_cp, w_in, m_planes, u_block, mask_block,
                         y_block, lmask_block, w0, mu, dt, hold_steps,
                         tableau_name: str = "rk4"):
    """`_tick_chunk_scan` + the chunked NLMS readout update, one dispatch
    (ExecPlan.learn="lms", core layout). Identical integration to the
    inference-only chunk; the learn tail carries only the (E, S, n_out)
    weight lanes — no inverse-Gram block rides the dispatch.
    Returns (m' (3, N, E), states (K, N, E), W', preds (K, E, n_out))."""
    mT, states = _tick_chunk_scan(
        params_e, w_cp, w_in, m_planes, u_block, mask_block, dt, hold_steps,
        tableau_name,
    )
    wT, preds = _lms_chunk_tail(states, y_block, lmask_block, w0, mu)
    return mT, states, wT, preds


# ---------------------------------------------------------------------------
# jit'd workers — kernel (3, N, E) planes layout ("ref"/"fused"/"tiled"/"chunk")
# ---------------------------------------------------------------------------


def _input_field(w_in, u, a_in, precision):
    """h_in = A_in * (W^in u) per lane, honoring the precision policy.

    "mixed" runs this GEMM — the 'field GEMM' of ExecPlan.precision — on
    bf16 operands with accumulation in the state dtype; every other policy
    keeps the exact op sequence the workers have always traced. u may be a
    single tick (E, N_in) or a chunk block (K, E, N_in).
    """
    eq = "ni,ei->ne" if u.ndim == 2 else "ni,kei->kne"
    scale = a_in[None, :] if u.ndim == 2 else a_in[None, None, :]
    return ops.input_field_einsum(eq, w_in, u, precision) * scale


@functools.partial(
    jax.jit,
    static_argnames=("dt", "hold_steps", "impl", "n_inner", "block_n", "block_e", "interpret", "precision"),
)
def _drive_planes(
    params_e, w_cp, w_in, m0_planes, u_seq_e,
    *, dt, hold_steps, impl, n_inner, block_n, block_e, interpret,
    precision="highest",
):
    """Ensemble drive through the kernel layout: per input sample, one
    hold-window integrate with the resolved impl."""
    e = m0_planes.shape[-1]
    pv = kref.pack_params(params_e, e, m0_planes.dtype)
    a_in = jnp.reshape(params_e.a_in, (-1,)) * jnp.ones((e,), m0_planes.dtype)

    def per_sample(m, u_t):  # u_t: (E, N_in)
        h = _input_field(w_in, u_t, a_in, precision)
        m = ops._integrate_planes_jit(
            m, w_cp, pv, h, None,
            dt=dt, n_steps=hold_steps, impl=impl, n_inner=n_inner,
            block_n=block_n, block_e=block_e, interpret=interpret,
            precision=precision,
        )
        return m, m[0]

    mT, states = jax.lax.scan(per_sample, m0_planes, u_seq_e)
    return mT, jnp.transpose(states, (0, 2, 1))  # (3, N, E), (T, E, N)


@functools.partial(
    jax.jit,
    static_argnames=("dt", "hold_steps", "impl", "n_inner", "block_n", "block_e", "interpret", "precision"),
)
def _tick_planes(
    params_e, w_cp, w_in, m_planes, u, mask,
    *, dt, hold_steps, impl, n_inner, block_n, block_e, interpret,
    precision="highest",
):
    """One hold window for a slot batch in the kernel layout; masked lanes
    come back bit-identical (partial-batch masking in kernels/ops.py)."""
    e = m_planes.shape[-1]
    pv = kref.pack_params(params_e, e, m_planes.dtype)
    a_in = jnp.reshape(params_e.a_in, (-1,)) * jnp.ones((e,), m_planes.dtype)
    h = _input_field(w_in, u, a_in, precision)
    m_new = ops._integrate_planes_jit(
        m_planes, w_cp, pv, h, mask,
        dt=dt, n_steps=hold_steps, impl=impl, n_inner=n_inner,
        block_n=block_n, block_e=block_e, interpret=interpret,
        precision=precision,
    )
    return m_new, m_new[0]


@functools.partial(
    jax.jit,
    static_argnames=("dt", "hold_steps", "impl", "n_inner", "block_n", "block_e", "interpret", "precision"),
)
def _tick_chunk_planes(
    params_e, w_cp, w_in, m_planes, u_block, mask_block,
    *, dt, hold_steps, impl, n_inner, block_n, block_e, interpret,
    precision="highest",
):
    """K serving ticks in one dispatch, kernel layout.

    For the per-window impls (ref/fused/tiled) the per-tick body is
    `_tick_planes`' exactly, with pack_params hoisted out of the K-loop (it
    is value-identical each tick). impl="chunk" is the chunk-resident path:
    the whole (K, N, E) input-field block is computed with ONE GEMM per
    chunk and handed to `ops.sto_rk4_tick_chunk_planes`' worker, which runs
    the K x hold_steps x 4-stage loop as one resident region (the Pallas
    rk4_chunk kernel on TPU). Returns ((3, N, E), (K, N, E))."""
    e = m_planes.shape[-1]
    pv = kref.pack_params(params_e, e, m_planes.dtype)
    a_in = jnp.reshape(params_e.a_in, (-1,)) * jnp.ones((e,), m_planes.dtype)

    if impl == "chunk":
        h_block = _input_field(w_in, u_block, a_in, precision)  # (K, N, E)
        return ops._tick_chunk_planes_jit(
            m_planes, w_cp, pv, h_block, mask_block,
            dt=dt, hold_steps=hold_steps, impl=impl, n_inner=n_inner,
            block_n=block_n, block_e=block_e, interpret=interpret,
            precision=precision,
        )

    def per_tick(m_c, tick_in):
        u_t, mask_t = tick_in
        h = _input_field(w_in, u_t, a_in, precision)
        m_new = ops._integrate_planes_jit(
            m_c, w_cp, pv, h, mask_t,
            dt=dt, n_steps=hold_steps, impl=impl, n_inner=n_inner,
            block_n=block_n, block_e=block_e, interpret=interpret,
            precision=precision,
        )
        return m_new, m_new[0]

    mT, states = jax.lax.scan(per_tick, m_planes, (u_block, mask_block))
    return mT, states  # (3, N, E), (K, N, E)


@functools.partial(
    jax.jit,
    static_argnames=("lam", "dt", "hold_steps", "impl", "n_inner", "block_n", "block_e", "interpret", "precision"),
)
def _tick_chunk_planes_rls(
    params_e, w_cp, w_in, m_planes, u_block, mask_block, y_block, lmask_block,
    p0, w0, *, lam, dt, hold_steps, impl, n_inner, block_n, block_e, interpret,
    precision="highest",
):
    """`_tick_chunk_planes` + the chunked RLS readout update, one dispatch
    (ExecPlan.learn="rls", kernel layout). The integrate may be a Pallas
    kernel; the learn tail is the same jnp `kernels.rls.rls_chunk` either
    way, applied to the chunk's (K, N, E) states block + bias. The learn
    recursion always runs in the state dtype — reduced precision stops at
    the readout-learning boundary (P's conditioning; see kernels/rls.py)."""
    mT, states = _tick_chunk_planes(
        params_e, w_cp, w_in, m_planes, u_block, mask_block,
        dt=dt, hold_steps=hold_steps, impl=impl, n_inner=n_inner,
        block_n=block_n, block_e=block_e, interpret=interpret,
        precision=precision,
    )
    pT, wT, preds = _learn_chunk_tail(states, y_block, lmask_block, p0, w0, lam)
    return mT, states, pT, wT, preds  # (3,N,E), (K,N,E), P', W', (K,E,n_out)


@functools.partial(
    jax.jit,
    static_argnames=("mu", "dt", "hold_steps", "impl", "n_inner", "block_n", "block_e", "interpret", "precision"),
)
def _tick_chunk_planes_lms(
    params_e, w_cp, w_in, m_planes, u_block, mask_block, y_block, lmask_block,
    w0, *, mu, dt, hold_steps, impl, n_inner, block_n, block_e, interpret,
    precision="highest",
):
    """`_tick_chunk_planes` + the chunked NLMS readout update, one dispatch
    (ExecPlan.learn="lms", kernel layout). Like the RLS twin, the learn tail
    always runs in the state dtype — reduced precision stops at the
    readout-learning boundary."""
    mT, states = _tick_chunk_planes(
        params_e, w_cp, w_in, m_planes, u_block, mask_block,
        dt=dt, hold_steps=hold_steps, impl=impl, n_inner=n_inner,
        block_n=block_n, block_e=block_e, interpret=interpret,
        precision=precision,
    )
    wT, preds = _lms_chunk_tail(states, y_block, lmask_block, w0, mu)
    return mT, states, wT, preds  # (3,N,E), (K,N,E), W', (K,E,n_out)


@functools.partial(
    jax.jit,
    static_argnames=("dt", "n_steps", "save_every", "impl", "n_inner", "block_n", "block_e", "interpret", "precision"),
)
def _integrate_planes(
    params_e, w_cp, m0_planes,
    *, dt, n_steps, save_every, impl, n_inner, block_n, block_e, interpret,
    precision="highest",
):
    """Free-run (u = 0) integration in the kernel layout."""
    e = m0_planes.shape[-1]
    pv = kref.pack_params(params_e, e, m0_planes.dtype)

    def chunk(m, length):
        return ops._integrate_planes_jit(
            m, w_cp, pv, None, None,
            dt=dt, n_steps=length, impl=impl, n_inner=n_inner,
            block_n=block_n, block_e=block_e, interpret=interpret,
            precision=precision,
        )

    if not save_every:
        return chunk(m0_planes, n_steps), None

    def body(m, _):
        m = chunk(m, save_every)
        return m, m

    mT, traj = jax.lax.scan(body, m0_planes, None, length=n_steps // save_every)
    return mT, traj


# ---------------------------------------------------------------------------
# jit'd workers — physics families (SimSpec.topology != "coupled_array")
#
# One chunk worker per layout covers every family: topology/readout_window
# are static arguments, so each family specializes its own executable while
# sharing this single code path (the family analogue of the "capabilities
# are fields, not entry points" rule). The coupled_array workers above are
# untouched — family dispatch happens in CompiledSim, so pre-family plans
# trace the identical graphs they always did.
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("topology", "readout_window", "hold_steps", "tableau_name"),
)
def _tick_chunk_scan_family(
    params_e, w_cp, w_in, m_planes, u_block, mask_block, dt,
    *, topology, readout_window, hold_steps, tableau_name="rk4",
):
    """K-tick family chunk in the core (E, N, 3) layout — the family oracle.

    topology="array_transient": `_tick_chunk_scan`'s coupled dynamics with
    the hold window split (hold_steps - w) + w and the emitted state the
    mean of the last w substeps' x-components — the same per-step op
    sequence, so readout_window=1 is bit-identical to `_tick_chunk_scan`.

    topology="time_multiplexed": one physical oscillator per lane
    (uncoupled core field, w_cp=None); the inner scan over the N virtual
    nodes is the delay line. Per tick the node drives are the masked input
    field plus the delayed feedback a_cp * (W^cp @ x_prev) from the
    previous tick's snapshots; row j of the state is node j's snapshot.
    """
    m = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)
    tableau = integrators.TABLEAUX[tableau_name]

    if topology == "time_multiplexed":

        def field(mm, h):
            return sto.llg_field(mm, params_e, None, h)  # single oscillator

        step = integrators.make_step(field, tableau)

        def per_tick(m_c, tick_in):
            u_t, mask_t = tick_in
            x_prev = m_c[..., 0]  # (E, N) previous tick's snapshots
            h = params_e.a_in * jnp.einsum("ni,ei->en", w_in, u_t)
            h = h + params_e.a_cp * jnp.einsum("nj,ej->en", w_cp, x_prev)
            s0 = m_c[:, -1:, :]  # carried oscillator state (E, 1, 3)

            def per_node(s, h_col):  # h_col (E,) — this node's drive
                def inner(si, _):
                    return step(si, dt, h_col[:, None]), None

                s_new, _ = jax.lax.scan(inner, s, None, length=hold_steps)
                return s_new, s_new[:, 0, :]  # snapshot (E, 3)

            sT, snaps = jax.lax.scan(per_node, s0, jnp.transpose(h))
            m_new = jnp.transpose(snaps, (1, 0, 2))  # (E, N, 3)
            m_new = jnp.where(mask_t[:, None, None], m_new, m_c)
            return m_new, jnp.transpose(m_new[..., 0])  # (N, E)

        mT, states = jax.lax.scan(per_tick, m, (u_block, mask_block))
        return jnp.transpose(mT, (2, 1, 0)), states  # (3, N, E), (K, N, E)

    # array_transient
    def field(mm, h):
        return sto.llg_field(mm, params_e, w_cp, h)

    step = integrators.make_step(field, tableau)
    w = int(readout_window)

    def per_tick(m_c, tick_in):
        u_t, mask_t = tick_in
        h_in = params_e.a_in * jnp.einsum("ni,ei->en", w_in, u_t)  # (E, N)

        def inner(mi, _):
            return step(mi, dt, h_in), None

        m_mid = m_c
        if hold_steps > w:
            m_mid, _ = jax.lax.scan(inner, m_c, None, length=hold_steps - w)

        def tail(mi, _):
            mi2 = step(mi, dt, h_in)
            return mi2, mi2[..., 0]  # (E, N)

        m_new, xs = jax.lax.scan(tail, m_mid, None, length=w)
        state = jnp.mean(xs, axis=0) if w > 1 else xs[0]
        m_new = jnp.where(mask_t[:, None, None], m_new, m_c)
        state = jnp.where(mask_t[:, None], state, m_c[..., 0])
        return m_new, jnp.transpose(state)  # (N, E)

    mT, states = jax.lax.scan(per_tick, m, (u_block, mask_block))
    return jnp.transpose(mT, (2, 1, 0)), states  # (3, N, E), (K, N, E)


@functools.partial(
    jax.jit,
    static_argnames=(
        "topology", "readout_window", "dt", "hold_steps", "impl", "n_inner",
        "block_n", "block_e", "interpret", "precision",
    ),
)
def _tick_chunk_planes_family(
    params_e, w_cp, w_in, m_planes, u_block, mask_block,
    *, topology, readout_window, dt, hold_steps, impl, n_inner, block_n,
    block_e, interpret, precision="highest",
):
    """K-tick family chunk in the kernel (3, N, E) planes layout.

    Every family computes the whole (K, N, E) input-field block with ONE
    GEMM per chunk (`_input_field`, "mixed" reduces it) and casts W once
    (`ops._coupling_operand`, "bf16_coupling"/"mixed" reduce it) — for
    time_multiplexed the W cast lands on the delayed-feedback GEMM, the
    family's one O(N^2) term. impl="ref" and impl="chunk" share one body
    per family (kernels/ref.py), so they are bit-identical by construction;
    array_transient under "fused"/"tiled" splits each hold window through
    the Pallas launchers ((hold - w) fused steps + w single steps).
    """
    e = m_planes.shape[-1]
    pv = kref.pack_params(params_e, e, m_planes.dtype)
    a_in = jnp.reshape(params_e.a_in, (-1,)) * jnp.ones((e,), m_planes.dtype)
    h_block = _input_field(w_in, u_block, a_in, precision)  # (K, N, E)
    w_c = ops._coupling_operand(w_cp, precision)

    if topology == "time_multiplexed":
        return kref.tm_chunk_planes(
            m_planes, w_c, pv, dt, hold_steps, h_block, mask_block
        )

    # array_transient
    if impl in ("ref", "chunk"):
        return kref.rk4_chunk_planes_window(
            m_planes, w_c, pv, dt, hold_steps, readout_window,
            h_block, mask_block,
        )

    w = int(readout_window)
    kw = dict(
        dt=dt, impl=impl, block_n=block_n, block_e=block_e,
        interpret=interpret, precision=precision,
    )

    def per_tick(m_c, tick_in):
        h_t, mask_t = tick_in
        m_mid = m_c
        if hold_steps > w:
            m_mid = ops._integrate_planes_jit(
                m_c, w_cp, pv, h_t, None,
                n_steps=hold_steps - w,
                n_inner=min(n_inner, hold_steps - w), **kw,
            )

        def tail(s, _):
            s2 = ops._integrate_planes_jit(
                s, w_cp, pv, h_t, None, n_steps=1, n_inner=1, **kw
            )
            return s2, s2[0]

        m_new, xs = jax.lax.scan(tail, m_mid, None, length=w)  # xs (w, N, E)
        state = jnp.mean(xs, axis=0) if w > 1 else xs[0]
        m_new = jnp.where(mask_t[None, None, :], m_new, m_c)
        state = jnp.where(mask_t[None, :], state, m_c[0])
        return m_new, state

    mT, states = jax.lax.scan(per_tick, m_planes, (h_block, mask_block))
    return mT, states  # (3, N, E), (K, N, E)


@functools.partial(
    jax.jit,
    static_argnames=(
        "learn", "knob", "topology", "readout_window", "hold_steps",
        "tableau_name",
    ),
)
def _tick_chunk_scan_family_learn(
    params_e, w_cp, w_in, m_planes, u_block, mask_block, y_block,
    lmask_block, p0, w0, dt,
    *, learn, knob, topology, readout_window, hold_steps, tableau_name,
):
    """Family chunk + online readout update, one dispatch (core layout).

    The learn tails are topology-blind — they consume the (K, N, E) states
    block whatever physics produced it — so families inherit both learners
    from the coupled path unchanged (learn="rls": knob=lam; "lms": knob=mu,
    p0=None)."""
    mT, states = _tick_chunk_scan_family(
        params_e, w_cp, w_in, m_planes, u_block, mask_block, dt,
        topology=topology, readout_window=readout_window,
        hold_steps=hold_steps, tableau_name=tableau_name,
    )
    if learn == "lms":
        wT, preds = _lms_chunk_tail(states, y_block, lmask_block, w0, knob)
        return mT, states, wT, preds
    pT, wT, preds = _learn_chunk_tail(states, y_block, lmask_block, p0, w0, knob)
    return mT, states, pT, wT, preds


@functools.partial(
    jax.jit,
    static_argnames=(
        "learn", "knob", "topology", "readout_window", "dt", "hold_steps",
        "impl", "n_inner", "block_n", "block_e", "interpret", "precision",
    ),
)
def _tick_chunk_planes_family_learn(
    params_e, w_cp, w_in, m_planes, u_block, mask_block, y_block,
    lmask_block, p0, w0,
    *, learn, knob, topology, readout_window, dt, hold_steps, impl, n_inner,
    block_n, block_e, interpret, precision="highest",
):
    """Family chunk + online readout update, one dispatch (planes layout).
    As everywhere else, the learn recursion runs in the state dtype —
    reduced precision stops at the readout-learning boundary."""
    mT, states = _tick_chunk_planes_family(
        params_e, w_cp, w_in, m_planes, u_block, mask_block,
        topology=topology, readout_window=readout_window, dt=dt,
        hold_steps=hold_steps, impl=impl, n_inner=n_inner, block_n=block_n,
        block_e=block_e, interpret=interpret, precision=precision,
    )
    if learn == "lms":
        wT, preds = _lms_chunk_tail(states, y_block, lmask_block, w0, knob)
        return mT, states, wT, preds
    pT, wT, preds = _learn_chunk_tail(states, y_block, lmask_block, p0, w0, knob)
    return mT, states, pT, wT, preds


# ---------------------------------------------------------------------------
# CompiledSim
# ---------------------------------------------------------------------------


class CompiledSim:
    """A SimSpec bound to resolved execution decisions. Build via compile_plan."""

    def __init__(self, spec: SimSpec, plan: ExecPlan, impl: str):
        self.spec = spec
        self.plan = plan
        self.impl = impl  # resolved: scan | ref | fused | tiled | chunk
        self.e = plan.ensemble
        self.topology = spec.topology
        self._readout_window = int(spec.readout_window)
        self._block_n = plan.block_n or ops.LANE
        self._block_e = plan.block_e or ops.LANE
        self._n_inner = plan.n_inner or spec.hold_steps
        self._dt_scan = jnp.asarray(spec.dt, spec.dtype)
        # static per-plan: the normalized precision tag the planes workers
        # specialize on ("highest" = bit-exact default) and the resolved
        # sharded gather dtype (precision subsumes the ad-hoc gather_dtype)
        self.precision = ops.normalize_precision(plan.precision)
        self._gather_dtype = plan.effective_gather_dtype
        # static: the learn workers specialize on their knob (RLS: lam == 1
        # skips the per-tick P rescale; LMS: mu is baked into the gain)
        self._lam = float(plan.learn_lam) if plan.learn else None
        self._mu = float(plan.learn_mu) if plan.learn == "lms" else None
        self._params_cache: Optional[STOParams] = None

    def init_learn_state(self) -> Tuple[Optional[jnp.ndarray], jnp.ndarray]:
        """Fresh learn_state lanes for the plan's learner, with S = N + 1
        (states + bias) and n_out = 1.

        learn="rls": (P (E, S, S) = I / learn_reg, W (E, S, 1) = 0).
        learn="lms": (None, W (E, S, 1) = 0) — LMS carries no P block; the
        None slot keeps the (P, W) tuple contract uniform across learners.
        Serving keeps these per-slot (SlotStore); callers driving tick_chunk
        by hand start here. For n_out != 1, call kernels.rls.rls_init /
        lms_init directly."""
        if self.plan.learn is None:
            raise ValueError("init_learn_state() requires ExecPlan(learn=...)")
        if self.plan.learn == "lms":
            return None, krls.lms_init(self.e, self.spec.n + 1, 1, self.spec.dtype)
        return krls.rls_init(
            self.e, self.spec.n + 1, 1, self.plan.learn_reg, self.spec.dtype
        )

    # -- parameter plumbing ------------------------------------------------

    def ensemble_params(self, params: Optional[STOParams] = None) -> STOParams:
        """Per-lane STOParams with (E, 1) leaves (scalar specs broadcast)."""
        if params is None:
            if self._params_cache is None:
                self._params_cache = self._broadcast(self.spec.params)
            return self._params_cache
        return self._broadcast(params)

    def _broadcast(self, p: STOParams) -> STOParams:
        from repro.core.ensemble import broadcast_params

        leaf = jnp.asarray(p.gamma)
        if leaf.ndim == 2 and leaf.shape == (self.e, 1):
            return p
        return broadcast_params(p, self.e)

    def _coerce_batch_u(self, u, keep_shared: bool = False) -> jnp.ndarray:
        """(T, N_in) shared or (T, E, N_in) per lane -> (T, E, N_in).

        keep_shared=True returns a valid shared series un-broadcast — the
        sharded path replicates it across devices instead of storing and
        contracting E per-lane copies.
        """
        spec = self.spec
        u = jnp.asarray(u, dtype=spec.dtype)
        if u.ndim == 2 and u.shape[1] == spec.n_in:
            if keep_shared:
                return u
            return jnp.broadcast_to(u[:, None, :], (u.shape[0], self.e, spec.n_in))
        if u.ndim == 3 and u.shape[1:] == (self.e, spec.n_in):
            return u
        raise ValueError(
            f"batch input series must have shape (T, {spec.n_in}) — shared "
            f"across lanes — or (T, {self.e}, {spec.n_in}) per lane; got "
            f"{tuple(u.shape)}"
        )

    def _coerce_batch_m0(self, m0) -> jnp.ndarray:
        spec = self.spec
        if m0 is None:
            return jnp.broadcast_to(spec.m0, (self.e, spec.n, 3))
        m0 = jnp.asarray(m0, dtype=spec.dtype)
        if m0.shape == (spec.n, 3):
            return jnp.broadcast_to(m0, (self.e, spec.n, 3))
        if m0.shape != (self.e, spec.n, 3):
            raise ValueError(
                f"m0 must have shape ({spec.n}, 3) or ({self.e}, {spec.n}, 3); "
                f"got {tuple(m0.shape)}"
            )
        return m0

    # -- entry points ------------------------------------------------------

    def drive(
        self, u_seq, m0: Optional[jnp.ndarray] = None
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Solo drive: input series (T, N_in) -> (final m (N, 3), states (T, N)).

        Requires ensemble == 1 and an unsharded plan; impl="scan" is
        bit-identical to the legacy `reservoir.drive`.
        """
        from repro.core.reservoir import coerce_input_series

        spec = self.spec
        if self.e != 1 or self.plan.sharded:
            raise ValueError(
                "drive() is the solo entry point (ensemble == 1, no mesh); "
                "use drive_batch() for ensemble/sharded plans"
            )
        u_seq = coerce_input_series(u_seq, spec.n_in, spec.dtype)
        m_start = spec.m0 if m0 is None else jnp.asarray(m0, dtype=spec.dtype)
        if m_start.shape != spec.m0.shape:
            raise ValueError(
                f"m0 must have shape {tuple(spec.m0.shape)}; got {tuple(m_start.shape)}"
            )
        if self.topology != "coupled_array":
            # families drive through their chunk worker: T ticks, one lane
            mT, states = self._family_chunk_infer(
                self.ensemble_params(), ops.to_planes(m_start),
                u_seq[:, None, :], jnp.ones((u_seq.shape[0], 1), dtype=bool),
            )
            return ops.from_planes(mT, ()), states[:, :, 0]
        if self.impl == "scan":
            # a (1, 1)-leaved ensemble-of-one spec is legal; the solo scan
            # math wants scalar leaves (identical values, broadcast-free)
            params = jax.tree.map(
                lambda x: jnp.reshape(x, ()) if jnp.asarray(x).ndim else x,
                spec.params,
            )
            return _drive_scan(
                params, spec.w_cp, spec.w_in, m_start, u_seq,
                spec.dt, spec.hold_steps, spec.tableau,
            )
        mT, states = _drive_planes(
            self.ensemble_params(), spec.w_cp, spec.w_in,
            ops.to_planes(m_start), u_seq[:, None, :],
            dt=float(spec.dt), hold_steps=spec.hold_steps, impl=self.impl,
            n_inner=self._n_inner, block_n=self._block_n,
            block_e=self._block_e, interpret=self.plan.interpret,
            precision=self.precision,
        )
        return ops.from_planes(mT, ()), states[:, 0, :]

    def drive_batch(
        self,
        u_seq,
        m0: Optional[jnp.ndarray] = None,
        params: Optional[STOParams] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Ensemble drive: E lanes, shared (T, N_in) or per-lane
        (T, E, N_in) input -> (mT (E, N, 3), states (T, E, N))."""
        spec = self.spec
        m0_e = self._coerce_batch_m0(m0)
        params_e = self.ensemble_params(params)
        if self.topology != "coupled_array":
            u_e = self._coerce_batch_u(u_seq)
            mT, states = self._family_chunk_infer(
                params_e, ops.to_planes(m0_e), u_e,
                jnp.ones((u_e.shape[0], self.e), dtype=bool),
            )
            return ops.from_planes(mT, (self.e,)), jnp.transpose(states, (0, 2, 1))
        if self.plan.sharded:
            # a shared series stays (T, N_in): replicated on every device,
            # contracted once per sample ('ni,i->n') instead of per lane
            u_sh = self._coerce_batch_u(u_seq, keep_shared=True)
            return _sharded.drive_sharded(
                self.plan.mesh, params_e, spec.w_cp, spec.w_in, m0_e, u_sh,
                spec.dt, spec.hold_steps,
                ensemble_axes=self.plan.ensemble_axes,
                model_axis=self.plan.model_axis,
                tableau_name=spec.tableau,
                gather_dtype=self._gather_dtype,
                precision=self.precision,
            )
        u_e = self._coerce_batch_u(u_seq)
        if self.impl == "scan":
            return _drive_scan_batch(
                params_e, spec.w_cp, spec.w_in, m0_e, u_e,
                spec.dt, spec.hold_steps, spec.tableau,
            )
        mT, states = _drive_planes(
            params_e, spec.w_cp, spec.w_in, ops.to_planes(m0_e), u_e,
            dt=float(spec.dt), hold_steps=spec.hold_steps, impl=self.impl,
            n_inner=self._n_inner, block_n=self._block_n,
            block_e=self._block_e, interpret=self.plan.interpret,
            precision=self.precision,
        )
        return ops.from_planes(mT, (self.e,)), states

    def integrate(
        self,
        n_steps: int,
        m0: Optional[jnp.ndarray] = None,
        save_every: int = 0,
        params: Optional[STOParams] = None,
    ):
        """Free-run (u = 0) integration of the E-lane ensemble.

        Returns (mT (E, N, 3), traj or None) — traj has shape
        (n_steps // save_every, E, N, 3) when save_every > 0. impl="scan"
        reproduces the legacy `ensemble.integrate_ensemble` exactly.
        """
        spec = self.spec
        if self.topology == "time_multiplexed":
            raise ValueError(
                "integrate() free-runs the coupled array; a time_multiplexed "
                "reservoir has no input-free virtual-node evolution — drive "
                "it with a zero input series instead"
            )
        # array_transient falls through: its free-run dynamics ARE the
        # coupled array's (the readout window only shapes emitted states)
        m0_e = self._coerce_batch_m0(m0)
        params_e = self.ensemble_params(params)
        if self.plan.sharded:
            if save_every:
                raise NotImplementedError("save_every on sharded plans")
            return (
                _sharded.integrate_sharded(
                    self.plan.mesh, params_e, spec.w_cp, m0_e, spec.dt, n_steps,
                    ensemble_axes=self.plan.ensemble_axes,
                    model_axis=self.plan.model_axis,
                    tableau_name=spec.tableau,
                    gather_dtype=self._gather_dtype,
                    precision=self.precision,
                ),
                None,
            )
        if self.impl == "scan":
            # unjitted like the legacy integrate_ensemble (lax.scan compiles
            # the trajectory either way; op-for-op identical results)
            tableau = integrators.TABLEAUX[spec.tableau]

            def field(m, _):
                return sto.llg_field(m, params_e, spec.w_cp)

            return integrators.integrate_scan(
                field, m0_e, spec.dt, n_steps, None, tableau, save_every=save_every
            )
        if save_every:
            assert n_steps % save_every == 0
        mT, traj = _integrate_planes(
            params_e, spec.w_cp, ops.to_planes(m0_e),
            dt=float(spec.dt), n_steps=n_steps, save_every=save_every,
            impl=self.impl, n_inner=self._n_inner, block_n=self._block_n,
            block_e=self._block_e, interpret=self.plan.interpret,
            precision=self.precision,
        )
        mT = ops.from_planes(mT, (self.e,))
        if traj is not None:
            traj = jax.vmap(lambda mp: ops.from_planes(mp, (self.e,)))(traj)
        return mT, traj

    def tick(
        self,
        m_planes: jnp.ndarray,  # (3, N, E) slot-store layout
        u: jnp.ndarray,  # (E, N_in) this tick's input row per lane
        lane_mask: Optional[jnp.ndarray] = None,  # (E,) bool; None = all active
        params: Optional[STOParams] = None,  # per-lane STOParams, (E, 1) leaves
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """ONE hold window for a slot batch — the serving hot path.

        Returns (m_planes' (3, N, E), states plane (N, E)). Lanes where
        lane_mask is False come back bit-identical (idle serving slots stay
        frozen while active slots advance in the same dispatch).
        """
        spec = self.spec
        params_e = self.ensemble_params(params)
        if lane_mask is None:
            lane_mask = jnp.ones((self.e,), dtype=bool)
        if self.topology != "coupled_array":
            # a tick is a K=1 chunk: one body per family keeps serving's
            # per-tick and chunked paths bit-identical by construction
            mT, states = self._family_chunk_infer(
                params_e, m_planes, u[None], jnp.asarray(lane_mask, bool)[None]
            )
            return mT, states[0]
        if self.plan.sharded:
            m = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)
            m_new, states = _sharded.tick_sharded(
                self.plan.mesh, params_e, spec.w_cp, spec.w_in, m, u, lane_mask,
                spec.dt, spec.hold_steps,
                ensemble_axes=self.plan.ensemble_axes,
                model_axis=self.plan.model_axis,
                tableau_name=spec.tableau,
                gather_dtype=self._gather_dtype,
                precision=self.precision,
            )
            return jnp.transpose(m_new, (2, 1, 0)), jnp.transpose(states)
        if self.impl == "scan":
            return _tick_scan(
                params_e, spec.w_cp, spec.w_in, m_planes, u, lane_mask,
                self._dt_scan, spec.hold_steps, spec.tableau,
            )
        return _tick_planes(
            params_e, spec.w_cp, spec.w_in, m_planes, u, lane_mask,
            dt=float(spec.dt), hold_steps=spec.hold_steps, impl=self.impl,
            n_inner=self._n_inner, block_n=self._block_n,
            block_e=self._block_e, interpret=self.plan.interpret,
            precision=self.precision,
        )

    def _coerce_tick_mask(self, lane_mask, k: int) -> jnp.ndarray:
        """(E,) or (K, E) bool -> (K, E) mask block (None = all active)."""
        if lane_mask is None:
            return jnp.ones((k, self.e), dtype=bool)
        lane_mask = jnp.asarray(lane_mask, dtype=bool)
        if lane_mask.shape == (self.e,):
            return jnp.broadcast_to(lane_mask[None, :], (k, self.e))
        if lane_mask.shape == (k, self.e):
            return lane_mask
        raise ValueError(
            f"lane_mask must have shape ({k}, {self.e}) or ({self.e},); "
            f"got {tuple(lane_mask.shape)}"
        )

    def tick_chunk(
        self,
        m_planes: jnp.ndarray,  # (3, N, E) slot-store layout
        u_block: jnp.ndarray,  # (K, E, N_in) input rows for K ticks
        lane_mask: Optional[jnp.ndarray] = None,  # (K, E) or (E,) bool
        params: Optional[STOParams] = None,  # per-lane STOParams, (E, 1) leaves
        targets: Optional[jnp.ndarray] = None,  # (K, E, n_out) learn targets
        learn_state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # (P, W)
        learn_mask: Optional[jnp.ndarray] = None,  # (K, E) or (E,) bool
    ):
        """K serving ticks (K hold windows) for a slot batch in ONE dispatch.

        The chunked serving hot path (`ExecPlan.chunk_ticks`): a lax.scan
        over the K input ticks keeps every intermediate states plane in a
        device-side buffer, so the host pays one transfer per chunk instead
        of one per tick. Returns (m_planes' (3, N, E), states (K, N, E)).

        lane_mask may be per tick (K, E) — a lane masked False for rows
        [0, k) and True after integrates exactly as if admitted at tick k
        (frozen lanes are bit-identical), and the mirror image retires a
        lane mid-chunk; or a single (E,) row applied to every tick. On the
        scan impl a K-chunk is bit-identical to K sequential `tick` calls
        (pinned by tests/test_serve_chunked.py); the planes impls and
        sharded plans agree within the kernel suite's tolerance.

        With `ExecPlan(learn="rls")` the chunk also LEARNS: pass
        `learn_state=(P (E, S, S), W (E, S, n_out))` (see
        `init_learn_state`) and `targets` (K, E, n_out), and every tick
        applies one masked batched RLS update (kernels/rls.py) to the learn
        lanes inside the same scan — no extra dispatches or host
        round-trips. `learn_mask` (default: lane_mask) gates which lanes
        learn which ticks; masked ticks leave P/W bit-identical, so
        washout, idle slots, and inference-only tenants all ride the same
        dispatch. Returns
        (m', states, (P', W'), preds (K, E, n_out)) — preds are the
        a-priori (pre-update) predictions. The integration itself is
        unchanged: m' and states are bit-identical to the inference-only
        chunk on every impl.
        """
        spec = self.spec
        params_e = self.ensemble_params(params)
        u_block = jnp.asarray(u_block, spec.dtype)
        if u_block.ndim != 3 or u_block.shape[1:] != (self.e, spec.n_in):
            raise ValueError(
                f"u_block must have shape (K, {self.e}, {spec.n_in}); "
                f"got {tuple(u_block.shape)}"
            )
        k = u_block.shape[0]
        mask_block = self._coerce_tick_mask(lane_mask, k)
        if self.plan.learn is None:
            if targets is not None or learn_state is not None or learn_mask is not None:
                raise ValueError(
                    "targets/learn_state/learn_mask require an "
                    "ExecPlan(learn='rls') plan; this plan is inference-only"
                )
            return self._tick_chunk_infer(params_e, m_planes, u_block, mask_block)
        if learn_state is None or targets is None:
            raise ValueError(
                f"ExecPlan(learn={self.plan.learn!r}) tick_chunk needs "
                "learn_state=(P, W) (P is None for learn='lms') and targets "
                "(K, E, n_out); for an inference-only chunk compile a plan "
                "with learn=None"
            )
        p0, w0 = learn_state
        n_out = w0.shape[-1]
        targets = jnp.asarray(targets, spec.dtype)
        if targets.shape != (k, self.e, n_out):
            raise ValueError(
                f"targets must have shape ({k}, {self.e}, {n_out}) to match "
                f"the u block and learn_state W lanes; got {tuple(targets.shape)}"
            )
        if w0.shape[:2] != (self.e, spec.n + 1):
            raise ValueError(
                f"learn_state W must have shape ({self.e}, {spec.n + 1}, "
                f"n_out); got {tuple(w0.shape)}"
            )
        lmask_block = (
            mask_block if learn_mask is None else self._coerce_tick_mask(learn_mask, k)
        )
        if self.topology != "coupled_array":
            return self._family_chunk_learn(
                params_e, m_planes, u_block, mask_block, targets, lmask_block,
                p0, w0,
            )
        if self.plan.learn == "lms":
            if p0 is not None:
                raise ValueError(
                    "learn='lms' carries no P block; pass learn_state="
                    "(None, W) (see init_learn_state)"
                )
            if self.impl == "scan":
                mT, states, wT, preds = _tick_chunk_scan_lms(
                    params_e, spec.w_cp, spec.w_in, m_planes, u_block,
                    mask_block, targets, lmask_block, w0, self._mu,
                    self._dt_scan, spec.hold_steps, spec.tableau,
                )
            else:
                mT, states, wT, preds = _tick_chunk_planes_lms(
                    params_e, spec.w_cp, spec.w_in, m_planes, u_block,
                    mask_block, targets, lmask_block, w0, mu=self._mu,
                    dt=float(spec.dt), hold_steps=spec.hold_steps,
                    impl=self.impl, n_inner=self._n_inner,
                    block_n=self._block_n, block_e=self._block_e,
                    interpret=self.plan.interpret, precision=self.precision,
                )
            return mT, states, (None, wT), preds
        if p0 is None or p0.shape != (self.e, spec.n + 1, spec.n + 1):
            raise ValueError(
                f"learn_state must be (P ({self.e}, {spec.n + 1}, "
                f"{spec.n + 1}), W ({self.e}, {spec.n + 1}, n_out)); got "
                f"P={None if p0 is None else tuple(p0.shape)}"
            )
        if self.plan.sharded:
            m = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)
            m_new, states, pT, wT, preds = _sharded.tick_chunk_sharded_rls(
                self.plan.mesh, params_e, spec.w_cp, spec.w_in, m,
                u_block, mask_block, targets, lmask_block, p0, w0,
                self._lam, spec.dt, spec.hold_steps,
                ensemble_axes=self.plan.ensemble_axes,
                model_axis=self.plan.model_axis,
                tableau_name=spec.tableau,
                gather_dtype=self._gather_dtype,
                precision=self.precision,
            )
            # states arrive (K, E, N): shuffle to the (K, N, E) block contract
            return (
                jnp.transpose(m_new, (2, 1, 0)),
                jnp.transpose(states, (0, 2, 1)),
                (pT, wT),
                preds,
            )
        if self.impl == "scan":
            mT, states, pT, wT, preds = _tick_chunk_scan_rls(
                params_e, spec.w_cp, spec.w_in, m_planes, u_block, mask_block,
                targets, lmask_block, p0, w0, self._lam,
                self._dt_scan, spec.hold_steps, spec.tableau,
            )
            return mT, states, (pT, wT), preds
        mT, states, pT, wT, preds = _tick_chunk_planes_rls(
            params_e, spec.w_cp, spec.w_in, m_planes, u_block, mask_block,
            targets, lmask_block, p0, w0, lam=self._lam,
            dt=float(spec.dt), hold_steps=spec.hold_steps, impl=self.impl,
            n_inner=self._n_inner, block_n=self._block_n,
            block_e=self._block_e, interpret=self.plan.interpret,
            precision=self.precision,
        )
        return mT, states, (pT, wT), preds

    def _family_chunk_infer(
        self, params_e, m_planes, u_block, mask_block
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Inference chunk for the non-coupled families (compile_plan keeps
        mesh plans out of here — families are unsharded by validation)."""
        spec = self.spec
        if self.impl == "scan":
            return _tick_chunk_scan_family(
                params_e, spec.w_cp, spec.w_in, m_planes, u_block, mask_block,
                self._dt_scan, topology=self.topology,
                readout_window=self._readout_window,
                hold_steps=spec.hold_steps, tableau_name=spec.tableau,
            )
        return _tick_chunk_planes_family(
            params_e, spec.w_cp, spec.w_in, m_planes, u_block, mask_block,
            topology=self.topology, readout_window=self._readout_window,
            dt=float(spec.dt), hold_steps=spec.hold_steps, impl=self.impl,
            n_inner=self._n_inner, block_n=self._block_n,
            block_e=self._block_e, interpret=self.plan.interpret,
            precision=self.precision,
        )

    def _family_chunk_learn(
        self, params_e, m_planes, u_block, mask_block, targets, lmask_block,
        p0, w0,
    ):
        """Learning chunk for the non-coupled families (same (P, W)/preds
        contract as the coupled learn paths)."""
        spec = self.spec
        learn = self.plan.learn
        if learn == "lms":
            if p0 is not None:
                raise ValueError(
                    "learn='lms' carries no P block; pass learn_state="
                    "(None, W) (see init_learn_state)"
                )
            knob = self._mu
        else:
            if p0 is None or p0.shape != (self.e, spec.n + 1, spec.n + 1):
                raise ValueError(
                    f"learn_state must be (P ({self.e}, {spec.n + 1}, "
                    f"{spec.n + 1}), W ({self.e}, {spec.n + 1}, n_out)); got "
                    f"P={None if p0 is None else tuple(p0.shape)}"
                )
            knob = self._lam
        if self.impl == "scan":
            out = _tick_chunk_scan_family_learn(
                params_e, spec.w_cp, spec.w_in, m_planes, u_block, mask_block,
                targets, lmask_block, p0, w0, self._dt_scan,
                learn=learn, knob=knob, topology=self.topology,
                readout_window=self._readout_window,
                hold_steps=spec.hold_steps, tableau_name=spec.tableau,
            )
        else:
            out = _tick_chunk_planes_family_learn(
                params_e, spec.w_cp, spec.w_in, m_planes, u_block, mask_block,
                targets, lmask_block, p0, w0,
                learn=learn, knob=knob, topology=self.topology,
                readout_window=self._readout_window, dt=float(spec.dt),
                hold_steps=spec.hold_steps, impl=self.impl,
                n_inner=self._n_inner, block_n=self._block_n,
                block_e=self._block_e, interpret=self.plan.interpret,
                precision=self.precision,
            )
        if learn == "lms":
            mT, states, wT, preds = out
            return mT, states, (None, wT), preds
        mT, states, pT, wT, preds = out
        return mT, states, (pT, wT), preds

    def _tick_chunk_infer(
        self, params_e, m_planes, u_block, mask_block
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Inference-only chunk body (plan.learn is None)."""
        spec = self.spec
        if self.topology != "coupled_array":
            return self._family_chunk_infer(params_e, m_planes, u_block, mask_block)
        if self.plan.sharded:
            m = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)
            m_new, states = _sharded.tick_chunk_sharded(
                self.plan.mesh, params_e, spec.w_cp, spec.w_in, m,
                u_block, mask_block, spec.dt, spec.hold_steps,
                ensemble_axes=self.plan.ensemble_axes,
                model_axis=self.plan.model_axis,
                tableau_name=spec.tableau,
                gather_dtype=self._gather_dtype,
                precision=self.precision,
            )
            # states arrive (K, E, N): shuffle to the (K, N, E) block contract
            return jnp.transpose(m_new, (2, 1, 0)), jnp.transpose(states, (0, 2, 1))
        if self.impl == "scan":
            return _tick_chunk_scan(
                params_e, spec.w_cp, spec.w_in, m_planes, u_block, mask_block,
                self._dt_scan, spec.hold_steps, spec.tableau,
            )
        return _tick_chunk_planes(
            params_e, spec.w_cp, spec.w_in, m_planes, u_block, mask_block,
            dt=float(spec.dt), hold_steps=spec.hold_steps, impl=self.impl,
            n_inner=self._n_inner, block_n=self._block_n,
            block_e=self._block_e, interpret=self.plan.interpret,
            precision=self.precision,
        )

    # -- warm-up / AOT -----------------------------------------------------

    def _warmup_inputs(self, n_out: int):
        """Representative zero-valued tick_chunk inputs: shapes and dtypes
        match what the serving loop dispatches (mask values never change
        the executable), so compiling on these warms the real hot path."""
        spec = self.spec
        k = max(self.plan.chunk_ticks, 1)
        m = ops.to_planes(jnp.broadcast_to(spec.m0, (self.e, spec.n, 3)))
        u = jnp.zeros((k, self.e, spec.n_in), spec.dtype)
        mask = jnp.zeros((k, self.e), dtype=bool)
        if self.plan.learn is None:
            return m, u, mask, None, None
        s = spec.n + 1
        if self.plan.learn == "lms":
            state = (None, krls.lms_init(self.e, s, n_out, spec.dtype))
        else:
            state = krls.rls_init(
                self.e, s, n_out, self.plan.learn_reg, spec.dtype
            )
        targets = jnp.zeros((k, self.e, n_out), spec.dtype)
        return m, u, mask, targets, state

    def warmup(self, n_out: int = 1) -> "CompiledSim":
        """Force XLA compilation of the chunked serving hot path by
        executing ONE all-lanes-masked zero chunk (per-FLOP cost of a
        single chunk; masked lanes make it state-neutral by construction).

        Unlike `aot_compile`, this populates the in-process jit fast path
        for the exact executable `tick_chunk` dispatches — an engine that
        rescales into a warmed bucket pays zero XLA work at the chunk
        boundary. Learn plans specialize on n_out (the readout width is a
        trace shape); pass the serving n_out to warm that variant.
        """
        m, u, mask, targets, state = self._warmup_inputs(n_out)
        if targets is None:
            out = self.tick_chunk(m, u, lane_mask=mask)
        else:
            out = self.tick_chunk(
                m, u, lane_mask=mask, targets=targets,
                learn_state=state, learn_mask=mask,
            )
        jax.block_until_ready(out[0])
        return self

    def _chunk_worker_call(self, n_out: int = 1):
        """(jitted worker, args, kwargs) for the exact module-level call
        tick_chunk dispatches — the AOT lowering target."""
        if self.plan.sharded:
            raise NotImplementedError(
                "AOT lowering covers unsharded plans; sharded plans warm by "
                "executing one masked chunk (CompiledSim.warmup)"
            )
        if self.topology != "coupled_array":
            raise NotImplementedError(
                "AOT lowering covers coupled_array plans; family plans warm "
                "by executing one masked chunk (CompiledSim.warmup)"
            )
        spec = self.spec
        params_e = self.ensemble_params()
        m, u, mask, targets, state = self._warmup_inputs(n_out)
        planes_kw = dict(
            dt=float(spec.dt), hold_steps=spec.hold_steps, impl=self.impl,
            n_inner=self._n_inner, block_n=self._block_n,
            block_e=self._block_e, interpret=self.plan.interpret,
            precision=self.precision,
        )
        if self.plan.learn is None:
            if self.impl == "scan":
                return _tick_chunk_scan, (
                    params_e, spec.w_cp, spec.w_in, m, u, mask,
                    self._dt_scan, spec.hold_steps, spec.tableau,
                ), {}
            return _tick_chunk_planes, (
                params_e, spec.w_cp, spec.w_in, m, u, mask,
            ), planes_kw
        p0, w0 = state
        if self.plan.learn == "lms":
            if self.impl == "scan":
                return _tick_chunk_scan_lms, (
                    params_e, spec.w_cp, spec.w_in, m, u, mask, targets,
                    mask, w0, self._mu, self._dt_scan, spec.hold_steps,
                    spec.tableau,
                ), {}
            return _tick_chunk_planes_lms, (
                params_e, spec.w_cp, spec.w_in, m, u, mask, targets,
                mask, w0,
            ), dict(mu=self._mu, **planes_kw)
        if self.impl == "scan":
            return _tick_chunk_scan_rls, (
                params_e, spec.w_cp, spec.w_in, m, u, mask, targets,
                mask, p0, w0, self._lam, self._dt_scan, spec.hold_steps,
                spec.tableau,
            ), {}
        return _tick_chunk_planes_rls, (
            params_e, spec.w_cp, spec.w_in, m, u, mask, targets,
            mask, p0, w0,
        ), dict(lam=self._lam, **planes_kw)

    def lower_tick_chunk(self, n_out: int = 1):
        """AOT-lower the chunked hot path (a `jax.stages.Lowered`).

        Raises NotImplementedError for sharded plans (use `warmup`)."""
        fn, args, kwargs = self._chunk_worker_call(n_out)
        return fn.lower(*args, **kwargs)

    def aot_compile(self, n_out: int = 1) -> "CompiledSim":
        """`lower().compile()` the chunked hot path without executing it.

        Zero FLOPs: the XLA compile happens now (and lands in the
        persistent compilation cache when one is configured — see
        `ExecPlan.compilation_cache_dir`) instead of at first dispatch.
        The in-process jit fast path still keys its own first call, so
        serving loops that must never stall use `warmup` instead; AOT is
        the restart-survival and compile-time-measurement path.
        """
        self.lower_tick_chunk(n_out).compile()
        return self


# ---------------------------------------------------------------------------
# compile_plan
# ---------------------------------------------------------------------------


def compile_plan(spec: SimSpec, plan: Optional[ExecPlan] = None, **overrides) -> CompiledSim:
    """Bind a SimSpec to an ExecPlan, resolving every execution decision.

    Keyword overrides build/amend the plan: `compile_plan(spec, ensemble=64)`
    == `compile_plan(spec, ExecPlan(ensemble=64))`. "auto" impls resolve
    against the measured-latency dispatch table (persisted per-platform JSON
    included); `measure=True` times the candidates for this (N, E) first and
    pins the winner, so the choice survives into the committed table via
    `kernels.dispatch_table.save_table()`.
    """
    if plan is None:
        plan = ExecPlan(**overrides)
    elif overrides:
        plan = dataclasses.replace(plan, **overrides)

    if plan.compilation_cache_dir:
        from repro.api import cache as _cache  # deferred: cache imports us

        _cache.enable_persistent_cache(plan.compilation_cache_dir)

    if spec.tableau not in integrators.TABLEAUX:
        raise ValueError(
            f"unknown tableau {spec.tableau!r}; choose from {sorted(integrators.TABLEAUX)}"
        )

    # physics-family validation: the spec's family invariants, then the
    # plan/family pairing (api/plan.FAMILY_IMPLS — e.g. the coupled-array
    # Pallas kernels cannot express the time-multiplexed delay line)
    from repro.api.spec import validate_topology

    validate_topology(spec)
    check_plan_supports_topology(plan, spec.topology)

    # fail here, with the fix spelled out, instead of deep inside a scan
    # trace: ensemble-leaved params must match the plan's width
    leaf = jnp.asarray(spec.params.gamma)
    if leaf.ndim == 2 and leaf.shape != (plan.ensemble, 1):
        raise ValueError(
            f"spec.params carries ensemble leaves of shape {tuple(leaf.shape)} "
            f"but the plan runs ensemble={plan.ensemble}; rebuild the sweep "
            f"with broadcast_params(base, {plan.ensemble}) or set "
            f"ExecPlan(ensemble={int(leaf.shape[0])})"
        )
    if leaf.ndim not in (0, 2):
        raise ValueError(
            f"spec.params leaves must be scalars or (E, 1) ensemble leaves "
            f"(broadcast_params); got shape {tuple(leaf.shape)}"
        )

    if plan.sharded:
        impl = "scan"  # sharded plans integrate in the core layout via shard_map
    else:
        impl = plan.impl
        if impl == "auto":
            # choose_impl lazily loads the persisted per-platform table;
            # both the measurement and the lookup are precision-keyed (the
            # impl ranking shifts when the coupling GEMM goes bf16)
            if plan.measure:
                # memoized through the process-wide PlanCache: identical
                # (platform, N, E, dtype, precision, K) keys are timed once
                from repro.api import cache as _cache

                _cache.PLAN_CACHE.measure(
                    spec.n, plan.ensemble, dt=float(spec.dt),
                    dtype=spec.dtype, precision=plan.effective_precision,
                    chunk_ticks=max(plan.chunk_ticks, 1),
                )
            impl = ops.choose_impl(
                spec.n, plan.ensemble, spec.dtype.itemsize,
                precision=plan.effective_precision,
            )
            if impl in ("fused", "tiled", "chunk") and spec.tableau != "rk4":
                # the table's winner was measured on RK4 workloads; an
                # auto plan with another tableau falls back to the oracle
                # instead of erroring on a choice the user never made
                impl = "ref"
            if (
                spec.topology == "time_multiplexed"
                and impl in ("fused", "tiled")
            ):
                # the table's winner was measured on the coupled array;
                # fall back rather than error on an auto-made choice
                impl = "ref"
    if impl in ("fused", "tiled", "chunk") and spec.tableau != "rk4":
        raise ValueError(
            f"the fused kernels integrate classical RK4 only; impl={impl!r} "
            f"cannot run tableau {spec.tableau!r} (use impl='scan' or 'ref')"
        )
    sim = CompiledSim(spec, plan, impl)
    if plan.aot:
        try:
            sim.aot_compile()
        except NotImplementedError:
            sim.warmup()
    return sim
