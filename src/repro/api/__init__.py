"""Unified execution API: SimSpec (what to simulate) x ExecPlan (how to run).

The paper's core claim is that the SAME reservoir evolution should be
dispatched to whichever implementation the hardware favors; this package is
that separation as an API:

    spec = api.make_spec(n=1024, hold_steps=100)           # pure physics
    sim = api.compile_plan(spec, ensemble=64)              # resolved exec
    mT, states = sim.drive_batch(U)                        # jit-cached run

Every impl-dispatch / padding / ensemble / sharding / learning decision in
the repo is made inside `compile_plan`; `core/reservoir.drive`,
`core/ensemble.integrate_ensemble{,_sharded}` are deprecation shims over
it, and `serve/reservoir.ReservoirEngine` serves from a CompiledSim —
sharded serving is just `ExecPlan(mesh=...)`, chunked serving
`ExecPlan(chunk_ticks=K)`, online readout learning `ExecPlan(learn="rls")`,
and reduced-precision execution `ExecPlan(precision="mixed")`.
Capabilities are added as ExecPlan fields, not new entry points
(docs/ARCHITECTURE.md).

Compilation itself is a shared, memoized resource: `PLAN_CACHE`
(repro.api.cache) maps (spec structural hash, plan key) -> CompiledSim so
autoscale buckets, fleet replicas, and tune combos compile once per
process — `PLAN_CACHE.get_or_compile(spec, plan)` is the cached analogue
of `compile_plan`, and `ExecPlan(compilation_cache_dir=...)` extends the
reuse across process restarts via JAX's persistent compilation cache.
"""

from repro.api.spec import (
    SimSpec,
    TOPOLOGIES,
    LANE_TUNABLE,
    STRUCT_TUNABLE,
    make_array_transient_spec,
    make_spec,
    make_time_multiplexed_spec,
    validate_topology,
)
from repro.api.plan import (
    ExecPlan,
    FAMILY_IMPLS,
    PLAN_IMPLS,
    PLAN_PRECISIONS,
    PLAN_TUNABLE,
    check_plan_supports_topology,
)
from repro.api.compiled import CompiledSim, compile_plan
from repro.api.cache import (
    PLAN_CACHE,
    PlanCache,
    enable_persistent_cache,
    plan_cache_key,
    spec_structural_hash,
)

__all__ = [
    "SimSpec",
    "TOPOLOGIES",
    "make_spec",
    "make_time_multiplexed_spec",
    "make_array_transient_spec",
    "validate_topology",
    "ExecPlan",
    "FAMILY_IMPLS",
    "check_plan_supports_topology",
    "PLAN_IMPLS",
    "PLAN_PRECISIONS",
    "LANE_TUNABLE",
    "STRUCT_TUNABLE",
    "PLAN_TUNABLE",
    "CompiledSim",
    "compile_plan",
    "PLAN_CACHE",
    "PlanCache",
    "enable_persistent_cache",
    "plan_cache_key",
    "spec_structural_hash",
]
