"""Process-wide compile cache: (spec_structural_hash, plan_key) -> CompiledSim.

XLA compilation — not the RK4 GEMMs — is the slowest path in this stack:
every autoscale bucket, fleet replica spin-up, and structural tune combo
used to call `compile_plan` from scratch. `PlanCache` makes compilation a
shared, memoized resource:

  spec_structural_hash   covers only the shape/dtype/topology-determining
                         SimSpec fields (n, n_in, dtype, dt, hold_steps,
                         tableau, and the *contents* of w_cp / w_in / m0).
                         Scalar STOParams VALUES are deliberately excluded:
                         they are lane-resident runtime inputs of every
                         backend ((E, 1) columns), so two specs differing
                         only in e.g. `a_cp` share one compiled simulator —
                         exactly the grouping the tune driver assumes.
                         Ensemble-leaved params contribute their shape
                         (the executable specializes on it), not values.
  plan_key               covers every ExecPlan field that changes the
                         compiled executable: impl, ensemble bucket,
                         padding/blocking, mesh decomposition (device ids +
                         axis layout), gather dtype, precision, chunk_ticks,
                         learn family + its static knobs, interpret, and
                         measure. Non-structural conveniences (aot,
                         compilation_cache_dir) are excluded — they change
                         *when* compilation happens, never its result.

Bit-exactness is guaranteed by construction: a cache hit returns the SAME
`CompiledSim` object a fresh `compile_plan` would rebuild (pinned by
tests/test_plan_cache.py against fresh compiles). The one exception is a
hit whose requested scalar param values differ from the cached sim's —
there the cache returns a cheap rebind (`CompiledSim(spec, plan, impl)`
around the requested spec) so callers always see their own values; the
rebind shares the module-level jit'd workers, so it costs no XLA work.

Thread safety: lookups and stats take one RLock; compilation itself runs
OUTSIDE the lock with a per-key in-flight `threading.Event`, so a serving
thread hitting `_rescale` while the background pre-warm thread is already
compiling that bucket WAITS for that one compile instead of duplicating it
— and compiles of other keys proceed concurrently.

The JAX persistent compilation cache rides along (`enable_persistent_cache`
/ `ExecPlan.compilation_cache_dir`): with a cache dir configured, the XLA
executables the workers compile are spilled to disk, so cold-start survives
process restarts (measured in BENCH_serve.json["compile"]).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
import warnings
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.api.compiled import CompiledSim, compile_plan
from repro.api.plan import ExecPlan
from repro.api.spec import SimSpec

__all__ = [
    "CacheStats",
    "PlanCache",
    "PLAN_CACHE",
    "enable_persistent_cache",
    "plan_cache_key",
    "spec_structural_hash",
]

_HASH_VERSION = b"spec-structural-v2"  # v2: physics-family fields joined the hash

#: Every SimSpec field spec_structural_hash accounts for. This is a FENCE:
#: the hash refuses to run on a spec whose field set it does not cover, so
#: adding a SimSpec field without deciding its hash treatment is a loud
#: TypeError at the first cache lookup, never a silent cross-physics cache
#: collision (pinned by tests/conformance/test_hash_guard.py).
_STRUCTURAL_FIELDS = (
    "params",
    "w_cp",
    "w_in",
    "m0",
    "dt",
    "hold_steps",
    "tableau",
    "topology",
    "readout_window",
)


def spec_structural_hash(spec: SimSpec) -> str:
    """Canonical hash of the compilation-relevant SimSpec fields.

    Two specs with the same hash compile to the same executable: same
    shapes, dtypes, topology contents, timestep, hold window, tableau, and
    physics family (topology tag + readout window — different families
    trace different workers, so they must never share a cache line).
    Scalar param values are excluded (lane-resident inputs); ensemble-leaved
    params contribute shape only.
    """
    unknown = set(spec._fields) - set(_STRUCTURAL_FIELDS)
    if unknown:
        raise TypeError(
            "spec_structural_hash does not cover SimSpec field(s) "
            f"{sorted(unknown)}; extend _STRUCTURAL_FIELDS in "
            "repro/api/cache.py (and bump _HASH_VERSION) so new physics "
            "fields key the cache instead of colliding"
        )
    h = hashlib.blake2b(digest_size=16)
    h.update(_HASH_VERSION)
    h.update(
        f"|{spec.n}|{spec.n_in}|{np.dtype(spec.dtype).name}"
        f"|{float(spec.dt)!r}|{int(spec.hold_steps)}|{spec.tableau}"
        f"|{spec.topology}|{int(spec.readout_window)}".encode()
    )
    for name in ("w_cp", "w_in", "m0"):
        a = np.asarray(getattr(spec, name))
        h.update(f"|{name}:{a.shape}:{a.dtype.name}:".encode())
        h.update(np.ascontiguousarray(a).tobytes())
    leaf = np.asarray(spec.params.gamma)
    h.update(f"|params:{leaf.shape}".encode())
    return h.hexdigest()


def _mesh_key(plan: ExecPlan):
    """Hashable description of the mesh decomposition (None when unsharded)."""
    if plan.mesh is None:
        return None
    mesh = plan.mesh
    shape = mesh.shape  # axis name -> size mapping
    return (
        tuple((str(k), int(v)) for k, v in shape.items()),
        tuple(str(d) for d in np.asarray(mesh.devices).flat),
        tuple(plan.ensemble_axes),
        plan.model_axis,
    )


def plan_cache_key(plan: ExecPlan) -> Tuple:
    """Canonical key over the ExecPlan fields that shape the executable.

    impl="auto" plans additionally carry the dispatch-table generation, so
    a cached auto-resolution is invalidated the moment a new measurement
    registers a different winner for its (N, E) cell.
    """
    from repro.kernels import dispatch_table, ops

    gd = plan.effective_gather_dtype
    if plan.impl == "auto" and not plan.sharded:
        # settle the lazy persisted-table load BEFORE reading the
        # generation, so the key only moves on genuinely new measurements
        dispatch_table.ensure_loaded()
        gen = ops.dispatch_generation()
    else:
        gen = None
    return (
        plan.impl,
        gen,
        int(plan.ensemble),
        plan.block_n,
        plan.block_e,
        plan.n_inner,
        _mesh_key(plan),
        None if gd is None else np.dtype(gd).name,
        ops.normalize_precision(plan.precision),
        int(plan.chunk_ticks),
        plan.learn,
        float(plan.learn_lam),
        float(plan.learn_reg),
        float(plan.learn_mu),
        bool(plan.interpret),
        bool(plan.measure),
    )


def _params_equal(a, b) -> bool:
    """Leaf-wise equality of two STOParams pytrees (shape + values)."""
    if a is b:
        return True
    for la, lb in zip(a, b):
        if la is lb:
            continue
        xa, xb = np.asarray(la), np.asarray(lb)
        if xa.shape != xb.shape or not np.array_equal(xa, xb):
            return False
    return True


# ---------------------------------------------------------------------------
# JAX persistent compilation cache (process restart survival)
# ---------------------------------------------------------------------------

_PERSISTENT_LOCK = threading.Lock()
_PERSISTENT_DIR: Optional[str] = None


def enable_persistent_cache(directory: str) -> bool:
    """Point JAX's persistent compilation cache at `directory` (idempotent).

    First configured directory wins for the process — JAX reads the config
    at compile time and re-pointing mid-flight would split the cache; a
    later call with a different directory warns and is ignored. Returns
    True when the cache is (now) active for `directory`.
    """
    global _PERSISTENT_DIR
    directory = str(directory)
    with _PERSISTENT_LOCK:
        if _PERSISTENT_DIR is not None:
            if _PERSISTENT_DIR != directory:
                warnings.warn(
                    "JAX persistent compilation cache already pinned to "
                    f"{_PERSISTENT_DIR!r}; ignoring {directory!r} (first "
                    "directory wins for the process)",
                    RuntimeWarning,
                    stacklevel=2,
                )
                return False
            return True
        try:
            os.makedirs(directory, exist_ok=True)
            jax.config.update("jax_compilation_cache_dir", directory)
        except Exception as exc:  # pragma: no cover - jax version gate
            warnings.warn(
                f"JAX persistent compilation cache unavailable: {exc!r}",
                RuntimeWarning,
                stacklevel=2,
            )
            return False
        # cache every executable, however small/fast the compile — this
        # stack's hot paths are many medium-sized modules, not one giant one
        for knob, value in (
            ("jax_persistent_cache_min_compile_time_secs", 0.0),
            ("jax_persistent_cache_min_entry_size_bytes", -1),
        ):
            try:
                jax.config.update(knob, value)
            except Exception:  # pragma: no cover - older jax
                pass
        # JAX initializes its disk cache lazily at the FIRST compile and
        # never re-reads the config: any compile before this call (spec
        # construction, dispatch probing) would freeze it disabled. Reset
        # so the next compile re-checks jax_compilation_cache_dir.
        try:
            from jax._src.compilation_cache import reset_cache

            reset_cache()
        except Exception:  # pragma: no cover - private API moved
            pass
        _PERSISTENT_DIR = directory
        return True


def persistent_cache_dir() -> Optional[str]:
    """The directory the persistent cache is pinned to (None = disabled)."""
    return _PERSISTENT_DIR


# ---------------------------------------------------------------------------
# PlanCache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    """Counters for the compile cache (see PlanCache.stats).

    hits/misses count `get_or_compile` lookups; compiles / compile_seconds
    cover the `compile_plan` calls misses triggered (compile_seconds is
    trace+bind time — the XLA work itself lands at first dispatch, which
    `warm` forces and times into warmups / warmup_seconds). rebinds counts
    hits that re-wrapped the cached executable around different scalar
    param values. measure_hits/measure_misses cover the memoized
    `measure_impl_latency` results (the `--save-dispatch-table` path).
    """

    hits: int = 0
    misses: int = 0
    compiles: int = 0
    compile_seconds: float = 0.0
    evictions: int = 0
    warmups: int = 0
    warmup_seconds: float = 0.0
    rebinds: int = 0
    measure_hits: int = 0
    measure_misses: int = 0

    def snapshot(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class PlanCache:
    """LRU cache of CompiledSims keyed (spec_structural_hash, plan_key)."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.RLock()
        self._entries: "OrderedDict[Tuple, CompiledSim]" = OrderedDict()
        self._warmed: set = set()
        self._inflight: Dict[Tuple, threading.Event] = {}
        self._measurements: Dict[Tuple, dict] = {}
        self.stats = CacheStats()

    # -- keys --------------------------------------------------------------

    def key(self, spec: SimSpec, plan: ExecPlan) -> Tuple:
        return (spec_structural_hash(spec), plan_cache_key(plan))

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def contains(self, spec: SimSpec, plan: Optional[ExecPlan] = None, **overrides) -> bool:
        """True when get_or_compile would hit (no stats mutation)."""
        plan = _resolve_plan(plan, overrides)
        key = self.key(spec, plan)
        with self._lock:
            return key in self._entries

    def is_warm(self, spec: SimSpec, plan: Optional[ExecPlan] = None, *, n_out: int = 1, **overrides) -> bool:
        """True when the (key, n_out) hot path has already been executed once."""
        plan = _resolve_plan(plan, overrides)
        key = self.key(spec, plan)
        with self._lock:
            return (key, int(n_out)) in self._warmed

    # -- the cache proper --------------------------------------------------

    def get_or_compile(
        self, spec: SimSpec, plan: Optional[ExecPlan] = None, **overrides
    ) -> CompiledSim:
        """The cached analogue of `compile_plan(spec, plan, **overrides)`.

        Hit: the cached CompiledSim (the same object), rebound to the
        requested spec when its scalar param values differ. Miss: compiles
        outside the lock (one in-flight compile per key — concurrent
        requesters wait on it) and inserts with LRU eviction.
        """
        plan = _resolve_plan(plan, overrides)
        if plan.compilation_cache_dir:
            enable_persistent_cache(plan.compilation_cache_dir)
        key = self.key(spec, plan)
        while True:
            with self._lock:
                sim = self._entries.get(key)
                if sim is not None:
                    self._entries.move_to_end(key)
                    self.stats.hits += 1
                    return self._rebind(sim, spec, plan)
                event = self._inflight.get(key)
                if event is None:
                    self._inflight[key] = threading.Event()
                    self.stats.misses += 1
                    break
            # another thread is compiling this key — wait, then re-check
            event.wait()
        try:
            t0 = time.perf_counter()
            sim = compile_plan(spec, plan)
            elapsed = time.perf_counter() - t0
        except BaseException:
            with self._lock:
                self._inflight.pop(key).set()  # waiters retry and re-raise
            raise
        with self._lock:
            self._entries[key] = sim
            self._entries.move_to_end(key)
            self.stats.compiles += 1
            self.stats.compile_seconds += elapsed
            while len(self._entries) > self.capacity:
                old_key, _ = self._entries.popitem(last=False)
                self._warmed = {w for w in self._warmed if w[0] != old_key}
                self.stats.evictions += 1
            self._inflight.pop(key).set()
        return sim

    def _rebind(self, sim: CompiledSim, spec: SimSpec, plan: ExecPlan) -> CompiledSim:
        """Hits always reflect the CALLER's param values: same structural
        hash + different scalar values -> cheap rewrap of the cached
        executable (module-level jit workers stay warm; zero XLA work)."""
        if _params_equal(sim.spec.params, spec.params):
            return sim
        with self._lock:
            self.stats.rebinds += 1
        return CompiledSim(spec, sim.plan, sim.impl)

    def warm(self, sim: CompiledSim, *, n_out: int = 1, aot: bool = False) -> float:
        """Force XLA compilation of `sim`'s chunked hot path, once per
        (key, n_out). Returns seconds spent (0.0 when already warm).

        aot=True lowers + compiles without executing (`lower().compile()`)
        — it populates the persistent disk cache and measures pure compile
        seconds, but the in-process jit fast path still pays one dispatch;
        the default executes one masked zero chunk, which warms the exact
        executable the serving loop dispatches.
        """
        key = (self.key(sim.spec, sim.plan), int(n_out))
        with self._lock:
            if key in self._warmed:
                return 0.0
        t0 = time.perf_counter()
        if aot:
            try:
                sim.aot_compile(n_out=n_out)
            except NotImplementedError:
                sim.warmup(n_out=n_out)
        else:
            sim.warmup(n_out=n_out)
        elapsed = time.perf_counter() - t0
        with self._lock:
            if key not in self._warmed:
                self._warmed.add(key)
                self.stats.warmups += 1
                self.stats.warmup_seconds += elapsed
        return elapsed

    def ensure_warm(
        self,
        spec: SimSpec,
        plan: Optional[ExecPlan] = None,
        *,
        n_out: int = 1,
        aot: bool = False,
        **overrides,
    ) -> CompiledSim:
        """get_or_compile + warm in one call (the pre-warm entry point)."""
        sim = self.get_or_compile(spec, plan, **overrides)
        self.warm(sim, n_out=n_out, aot=aot)
        return sim

    # -- measurement memo (compile_plan(measure=True)) ---------------------

    def measure(
        self,
        n: int,
        e: int,
        *,
        dt: float,
        n_steps: int = 8,
        candidates: Optional[Tuple[str, ...]] = None,
        dtype=None,
        reps: int = 3,
        precision: Optional[str] = None,
        chunk_ticks: int = 4,
    ) -> dict:
        """Memoized `ops.measure_impl_latency`: identical keys in one
        process are timed once — repeated `compile_plan(measure=True)` /
        `--save-dispatch-table` runs stop paying duplicate candidate
        timing. The first call still registers its winner in the dispatch
        table (register=True), so resolution is unchanged."""
        import jax.numpy as jnp

        from repro.kernels import ops

        dtype = jnp.float32 if dtype is None else dtype
        key = (
            jax.default_backend(),
            int(n),
            int(e),
            np.dtype(dtype).name,
            ops.normalize_precision(precision),
            int(chunk_ticks),
            int(n_steps),
            int(reps),
            None if candidates is None else tuple(candidates),
        )
        with self._lock:
            memo = self._measurements.get(key)
            if memo is not None:
                self.stats.measure_hits += 1
                return memo
        timings = ops.measure_impl_latency(
            n, e, dt=dt, n_steps=n_steps, candidates=candidates,
            dtype=dtype, reps=reps, precision=precision,
            chunk_ticks=chunk_ticks,
        )
        with self._lock:
            self.stats.measure_misses += 1
            self._measurements[key] = timings
        return timings

    # -- maintenance -------------------------------------------------------

    def clear(self) -> None:
        """Drop every entry, warm mark, and measurement memo (stats kept)."""
        with self._lock:
            self._entries.clear()
            self._warmed.clear()
            self._measurements.clear()


def _resolve_plan(plan: Optional[ExecPlan], overrides: dict) -> ExecPlan:
    if plan is None:
        return ExecPlan(**overrides)
    if overrides:
        return dataclasses.replace(plan, **overrides)
    return plan


#: The process-wide cache every compile hot path shares: ReservoirEngine
#: autoscale buckets, fleet replica spin-up / migration warm-start, the
#: capacity planner's recalibration probe, and tune_spec's per-structural-
#: combo engines all draw from here.
PLAN_CACHE = PlanCache()
