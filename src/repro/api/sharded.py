"""Sharded execution bodies for ExecPlan(mesh=...) plans.

The shard_map decomposition (formerly core/ensemble.py, now owned by the
unified API): the ensemble axis E spans the data/pod mesh axes and the
oscillator axis N spans the model axis. W^cp is row-sharded and each RK
stage all-gathers the m^x slice (N*E_local floats — negligible next to the
O(N^2 E) compute). PartitionSpecs come from
`distributed.sharding.reservoir_specs` so every sharded reservoir path in
the repo agrees on the layout.

`gather_dtype` (e.g. jnp.bfloat16) runs the COUPLING PATH in reduced
precision: m^x is cast before the all-gather (half the wire bytes) and the
coupling matmul runs bf16 x bf16 -> f32 (MXU-native accumulate). Consuming
bf16 directly in the dot is what keeps XLA from cancelling the converts
around the collective and silently restoring an f32 gather (observed;
§Perf C). Physically benign: |H_cp| <= A_cp ~ 1 Oe against ~600 Oe local
fields, and |m|=1 conservation is structural.

`ExecPlan.precision` subsumes that ad-hoc knob: "bf16_coupling"/"mixed"
plans resolve to gather_dtype=bf16 (an explicit gather_dtype still wins —
see ExecPlan.effective_gather_dtype), and "mixed" additionally runs the
input-field GEMM (W^in u) on bf16 operands (`_input_field_local`). The
`precision` argument on every body here is part of the lru_cache key, so
plans of different precision never share a trace.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.compat import SHARD_MAP_CHECK_KW as _SHARD_MAP_CHECK_KW
from repro.core.compat import shard_map
from repro.core import integrators, sto
from repro.core.constants import STOParams
from repro.distributed.sharding import reservoir_specs
from repro.kernels import rls as krls


def _input_field_local(params_l, win_l, u_t, precision, per_lane=True):
    """h_in = A_in * (W^in_local u_t), honoring the precision policy.

    The reduction policy itself lives in `kernels.ops.input_field_einsum`
    (shared with the planes workers); this wrapper owns the sharded
    layouts and the legacy a_in op order.
    """
    from repro.kernels import ops as kops

    eq = "ni,ei->en" if per_lane else "ni,i->n"
    return params_l.a_in * kops.input_field_einsum(eq, win_l, u_t, precision)


def _coupling_field(params_l, w_mm, m, model_axis, gather_dtype):
    """h_x = A_cp * W^cp_local @ all-gather(m^x): the one collective per stage."""
    mx = m[..., 0]  # (E_l, N_l)
    if gather_dtype is not None:
        mx = mx.astype(gather_dtype)
    if model_axis is not None:
        mx_full = jax.lax.all_gather(mx, model_axis, axis=-1, tiled=True)
    else:
        mx_full = mx
    return params_l.a_cp * jnp.einsum(
        "ki,...i->...k", w_mm, mx_full, preferred_element_type=m.dtype
    )


def integrate_sharded(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    m0: jnp.ndarray,  # (E, N, 3)
    dt: float,
    n_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
    precision=None,  # free-run has no input GEMM; coupling rides gather_dtype
):
    """Free-running (u = 0) sharded ensemble integration -> final (E, N, 3)."""
    tableau = integrators.TABLEAUX[tableau_name]
    specs = reservoir_specs(ensemble_axes, model_axis)

    def local_run(params_l: STOParams, w_l, m0_l):
        w_mm = w_l.astype(gather_dtype) if gather_dtype is not None else w_l

        def field(m, _):
            h_x = _coupling_field(params_l, w_mm, m, model_axis, gather_dtype)
            b = sto.effective_field_b(m, params_l, h_x)
            return sto.llg_rhs_from_b(m, b, params_l)

        yT, _ = integrators.integrate_scan(field, m0_l, dt, n_steps, None, tableau)
        return yT

    fn = shard_map(
        local_run,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: specs["params"], params),
            specs["w"],
            specs["m"],
        ),
        out_specs=specs["m"],
        **_SHARD_MAP_CHECK_KW,
    )
    return fn(params, w_cp, m0)


def drive_sharded(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    w_in: jnp.ndarray,  # (N, N_in)
    m0: jnp.ndarray,  # (E, N, 3)
    u_seq: jnp.ndarray,  # (T, N_in) shared series OR (T, E, N_in) per lane
    dt: float,
    hold_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
    precision=None,
):
    """Reservoir DRIVE (input on) for a sharded ensemble.

    Returns (mT (E, N, 3), states (T, E, N)) with states = m^x sampled after
    each hold window — the full paper application (sweep + drive + readout)
    on the production mesh. The input field h_in = A_in * (W_in u_t) depends
    only on the LOCAL N rows, so the input path adds no collectives; only
    the coupling gathers.
    """
    tableau = integrators.TABLEAUX[tableau_name]
    specs = reservoir_specs(ensemble_axes, model_axis)
    per_lane_u = u_seq.ndim == 3

    def local_run(params_l: STOParams, w_l, win_l, m0_l, u):
        w_mm = w_l.astype(gather_dtype) if gather_dtype is not None else w_l

        def field(m, h_in_x):
            h_x = _coupling_field(params_l, w_mm, m, model_axis, gather_dtype)
            h_x = h_x + h_in_x
            b = sto.effective_field_b(m, params_l, h_x)
            return sto.llg_rhs_from_b(m, b, params_l)

        step = integrators.make_step(field, tableau)
        dt_c = jnp.asarray(dt, m0_l.dtype)

        def per_sample(m, u_t):
            h_in = _input_field_local(params_l, win_l, u_t, precision, per_lane_u)
            h_in = jnp.broadcast_to(h_in, m[..., 0].shape)

            def inner(mi, _):
                return step(mi, dt_c, h_in), None

            m, _ = jax.lax.scan(inner, m, None, length=hold_steps)
            return m, m[..., 0]

        mT, states = jax.lax.scan(per_sample, m0_l, u)
        return mT, states

    fn = shard_map(
        local_run,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: specs["params"], params),
            specs["w"],
            specs["w_in"],
            specs["m"],
            specs["u_e"] if per_lane_u else specs["u"],
        ),
        out_specs=(specs["m"], specs["states"]),
        **_SHARD_MAP_CHECK_KW,
    )
    return fn(params, w_cp, w_in, m0, u_seq)


@functools.lru_cache(maxsize=None)
def _tick_sharded_fn(
    mesh: Mesh,
    ensemble_axes: tuple,
    model_axis: Optional[str],
    tableau_name: str,
    dt: float,
    hold_steps: int,
    gather_dtype,
    precision=None,
):
    """Build (once per signature) the jit'd shard_map'd tick.

    The serving engine calls the tick every input sample — a fresh shard_map
    closure per call would defeat JAX's compilation cache and retrace the
    whole hold-window scan each tick, so the wrapped callable is cached on
    everything that shapes the trace (mesh/axes/tableau/dt/hold/gather).
    """
    tableau = integrators.TABLEAUX[tableau_name]
    specs = reservoir_specs(ensemble_axes, model_axis)

    def local_run(params_l: STOParams, w_l, win_l, m_l, u_l, mask_l):
        w_mm = w_l.astype(gather_dtype) if gather_dtype is not None else w_l

        def field(mm, h_in_x):
            h_x = _coupling_field(params_l, w_mm, mm, model_axis, gather_dtype)
            h_x = h_x + h_in_x
            b = sto.effective_field_b(mm, params_l, h_x)
            return sto.llg_rhs_from_b(mm, b, params_l)

        step = integrators.make_step(field, tableau)
        dt_c = jnp.asarray(dt, m_l.dtype)
        h_in = _input_field_local(params_l, win_l, u_l, precision)  # (E_l, N_l)

        def inner(mi, _):
            return step(mi, dt_c, h_in), None

        m_new, _ = jax.lax.scan(inner, m_l, None, length=hold_steps)
        m_new = jnp.where(mask_l[:, None, None], m_new, m_l)
        return m_new, m_new[..., 0]

    p_params = STOParams(*([specs["params"]] * len(STOParams._fields)))
    return jax.jit(
        shard_map(
            local_run,
            mesh=mesh,
            in_specs=(
                p_params,
                specs["w"],
                specs["w_in"],
                specs["m"],
                specs["u_tick"],
                specs["lane"],
            ),
            out_specs=(specs["m"], specs["states_tick"]),
            **_SHARD_MAP_CHECK_KW,
        )
    )


@functools.lru_cache(maxsize=None)
def _tick_chunk_sharded_fn(
    mesh: Mesh,
    ensemble_axes: tuple,
    model_axis: Optional[str],
    tableau_name: str,
    dt: float,
    hold_steps: int,
    gather_dtype,
    precision=None,
):
    """Build (once per signature) the jit'd shard_map'd K-tick chunk.

    Chunked serving's sharded path: the local body scans over the K input
    ticks, so per-tick states stay device-side and shard-local until the
    engine's once-per-chunk harvest. Cached like `_tick_sharded_fn` — the
    engine calls this every chunk and a fresh closure would retrace.
    """
    tableau = integrators.TABLEAUX[tableau_name]
    specs = reservoir_specs(ensemble_axes, model_axis)

    def local_run(params_l: STOParams, w_l, win_l, m_l, u_l, mask_l):
        # u_l: (K, E_l, N_in), mask_l: (K, E_l)
        w_mm = w_l.astype(gather_dtype) if gather_dtype is not None else w_l

        def field(mm, h_in_x):
            h_x = _coupling_field(params_l, w_mm, mm, model_axis, gather_dtype)
            h_x = h_x + h_in_x
            b = sto.effective_field_b(mm, params_l, h_x)
            return sto.llg_rhs_from_b(mm, b, params_l)

        step = integrators.make_step(field, tableau)
        dt_c = jnp.asarray(dt, m_l.dtype)

        def per_tick(m_c, tick_in):
            u_t, mask_t = tick_in
            h_in = _input_field_local(params_l, win_l, u_t, precision)

            def inner(mi, _):
                return step(mi, dt_c, h_in), None

            m_new, _ = jax.lax.scan(inner, m_c, None, length=hold_steps)
            m_new = jnp.where(mask_t[:, None, None], m_new, m_c)
            return m_new, m_new[..., 0]

        mT, states = jax.lax.scan(per_tick, m_l, (u_l, mask_l))
        return mT, states  # (E_l, N_l, 3), (K, E_l, N_l)

    p_params = STOParams(*([specs["params"]] * len(STOParams._fields)))
    return jax.jit(
        shard_map(
            local_run,
            mesh=mesh,
            in_specs=(
                p_params,
                specs["w"],
                specs["w_in"],
                specs["m"],
                specs["u_e"],
                specs["lane_block"],
            ),
            out_specs=(specs["m"], specs["states"]),
            **_SHARD_MAP_CHECK_KW,
        )
    )


@functools.lru_cache(maxsize=None)
def _tick_chunk_sharded_rls_fn(
    mesh: Mesh,
    ensemble_axes: tuple,
    model_axis: Optional[str],
    tableau_name: str,
    dt: float,
    hold_steps: int,
    gather_dtype,
    lam: float,  # static: the RLS update specializes on it (kernels/rls.py)
    precision=None,
):
    """Build (once per signature) the jit'd shard_map'd learning K-chunk.

    `_tick_chunk_sharded_fn` + the chunked RLS readout update
    (ExecPlan.learn="rls"). P and W ride LANE-sharded — the ensemble axes
    split E, the (S, S) feature block is replicated — while the feature
    block (the full N node states + bias) is all-gathered over the model
    axis ONCE per chunk, like the coupling field's m^x but K ticks at a
    time; `kernels.rls.rls_chunk` then runs shard-locally on the lane
    shard.
    """
    tableau = integrators.TABLEAUX[tableau_name]
    specs = reservoir_specs(ensemble_axes, model_axis)

    def local_run(params_l: STOParams, w_l, win_l, m_l, u_l, mask_l,
                  y_l, lmask_l, p_l, wl_l):
        # u_l (K, E_l, N_in), mask_l/lmask_l (K, E_l), y_l (K, E_l, n_out),
        # p_l (E_l, S, S), wl_l (E_l, S, n_out)
        w_mm = w_l.astype(gather_dtype) if gather_dtype is not None else w_l

        def field(mm, h_in_x):
            h_x = _coupling_field(params_l, w_mm, mm, model_axis, gather_dtype)
            h_x = h_x + h_in_x
            b = sto.effective_field_b(mm, params_l, h_x)
            return sto.llg_rhs_from_b(mm, b, params_l)

        step = integrators.make_step(field, tableau)
        dt_c = jnp.asarray(dt, m_l.dtype)

        def per_tick(m_c, tick_in):
            u_t, mask_t = tick_in
            h_in = _input_field_local(params_l, win_l, u_t, precision)

            def inner(mi, _):
                return step(mi, dt_c, h_in), None

            m_new, _ = jax.lax.scan(inner, m_c, None, length=hold_steps)
            m_new = jnp.where(mask_t[:, None, None], m_new, m_c)
            return m_new, m_new[..., 0]

        mT, states = jax.lax.scan(per_tick, m_l, (u_l, mask_l))
        # full-N feature block for the lane-sharded learn state: one gather
        # per chunk over the model axis (K, E_l, N_l) -> (K, E_l, N)
        sx = states
        if model_axis is not None:
            sx = jax.lax.all_gather(sx, model_axis, axis=-1, tiled=True)
        xb = jnp.concatenate(
            [sx, jnp.ones((*sx.shape[:2], 1), sx.dtype)], axis=-1
        )
        pT, wT, preds = krls.rls_chunk(p_l, wl_l, xb, y_l, lmask_l, lam)
        return mT, states, pT, wT, preds

    p_params = STOParams(*([specs["params"]] * len(STOParams._fields)))
    return jax.jit(
        shard_map(
            local_run,
            mesh=mesh,
            in_specs=(
                p_params,
                specs["w"],
                specs["w_in"],
                specs["m"],
                specs["u_e"],
                specs["lane_block"],
                specs["y_block"],
                specs["lane_block"],
                specs["learn_p"],
                specs["learn_w"],
            ),
            out_specs=(
                specs["m"],
                specs["states"],
                specs["learn_p"],
                specs["learn_w"],
                specs["y_block"],
            ),
            **_SHARD_MAP_CHECK_KW,
        )
    )


def tick_chunk_sharded_rls(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    w_in: jnp.ndarray,  # (N, N_in)
    m: jnp.ndarray,  # (E, N, 3)
    u_block: jnp.ndarray,  # (K, E, N_in)
    mask_block: jnp.ndarray,  # (K, E) bool — integration lane mask
    y_block: jnp.ndarray,  # (K, E, n_out) per-tick learning targets
    lmask_block: jnp.ndarray,  # (K, E) bool — which lanes LEARN which ticks
    p0: jnp.ndarray,  # (E, S, S) per-lane RLS inverse-Gram
    w0: jnp.ndarray,  # (E, S, n_out) per-lane readout weights
    lam: float,  # forgetting factor (static)
    dt: float,
    hold_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
    precision=None,
):
    """K learning serving ticks for a sharded slot batch in one dispatch.

    The sharded analogue of the learn branch of `CompiledSim.tick_chunk`:
    integration is `tick_chunk_sharded`'s exactly; the fused RLS update
    keeps P/W lane-sharded and all-gathers the feature vector over the
    model axis. Returns (m' (E, N, 3), states (K, E, N), P', W',
    preds (K, E, n_out)).
    """
    fn = _tick_chunk_sharded_rls_fn(
        mesh, tuple(ensemble_axes), model_axis, tableau_name,
        float(dt), int(hold_steps), gather_dtype, float(lam), precision,
    )
    return fn(params, w_cp, w_in, m, u_block, mask_block,
              y_block, lmask_block, p0, w0)


def tick_chunk_sharded(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    w_in: jnp.ndarray,  # (N, N_in)
    m: jnp.ndarray,  # (E, N, 3)
    u_block: jnp.ndarray,  # (K, E, N_in) input rows for K ticks
    mask_block: jnp.ndarray,  # (K, E) bool; False = lane frozen that tick
    dt: float,
    hold_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
    precision=None,
):
    """K serving ticks for a sharded slot batch in one dispatch.

    The sharded analogue of `CompiledSim.tick_chunk`: per-tick lane masks
    support mid-chunk admit/retire (masked ticks are bit-identical), and the
    (K, E, N) states block stays on device until the engine's bulk harvest.
    Returns (m' (E, N, 3), states (K, E, N)).
    """
    fn = _tick_chunk_sharded_fn(
        mesh, tuple(ensemble_axes), model_axis, tableau_name,
        float(dt), int(hold_steps), gather_dtype, precision,
    )
    return fn(params, w_cp, w_in, m, u_block, mask_block)


def tick_sharded(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    w_in: jnp.ndarray,  # (N, N_in)
    m: jnp.ndarray,  # (E, N, 3)
    u: jnp.ndarray,  # (E, N_in) — this tick's input row per lane
    lane_mask: jnp.ndarray,  # (E,) bool; False lanes return unchanged
    dt: float,
    hold_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
    precision=None,
):
    """One serving tick (a full hold window) for a sharded slot batch.

    The sharded analogue of the engine's batched tick: per-tenant params ride
    in the (E, 1) leaves, the input row is per lane, and masked lanes come
    back bit-identical so idle serving slots stay frozen. Returns
    (m' (E, N, 3), states (E, N)).
    """
    fn = _tick_sharded_fn(
        mesh, tuple(ensemble_axes), model_axis, tableau_name,
        float(dt), int(hold_steps), gather_dtype, precision,
    )
    return fn(params, w_cp, w_in, m, u, lane_mask)
