"""SimSpec: WHAT to simulate — the pure physics of a coupled-STO reservoir.

A `SimSpec` is everything the paper's equations need and nothing the
hardware cares about: the LLG/STO parameter set, the coupling and input
topologies, the initial magnetization, the RK timestep/tableau, and the
hold window (integration steps per input sample). How that evolution is
executed — impl choice, padding, ensemble batching, sharding — lives in
`repro.api.plan.ExecPlan`; `repro.api.compile_plan(spec, plan)` marries the
two.

`SimSpec` subsumes `repro.core.reservoir.Reservoir` (same leading fields,
plus the tableau); `SimSpec.from_reservoir` / `.to_reservoir` convert
losslessly, so legacy call sites interoperate during the migration.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import constants, coupling
from repro.core.constants import STOParams

# Knob classification for `repro.tune` (and anything else sweeping specs).
#
# LANE_TUNABLE fields vary PER ENSEMBLE LANE of one CompiledSim: they are
# exactly the STOParams leaves, which every backend reads as (E, 1) columns
# — so E candidates with different values ride ONE dispatch (a_cp is the
# effective spectral radius: make_coupling_matrix normalizes W^cp to
# rho = 1, so the per-lane a_cp scale IS rho of the effective coupling).
#
# STRUCT_TUNABLE fields are STRUCTURAL: dt and hold_steps are static
# arguments of the jit'd workers (dt scales every RK stage, hold_steps is
# a scan length), so changing them means a different compiled simulator —
# searches over them group candidates per value (repro.tune compiles one
# engine per structural combination and sweeps lane knobs within each).
LANE_TUNABLE = STOParams._fields
STRUCT_TUNABLE = ("dt", "hold_steps")

# The physics families one SimSpec can describe (docs/ARCHITECTURE.md
# "Physics families"). Following the repo rule — capabilities are
# SimSpec/ExecPlan fields, not new entry points — a family is a `topology`
# value, not a new class:
#
#   coupled_array     the paper's N-coupled STO array (the default; every
#                     pre-family spec is this, so hashes/semantics of
#                     existing specs are unchanged).
#   time_multiplexed  Riou et al. (arXiv:1904.1236): ONE oscillator, N
#                     virtual nodes realized by masking its input over a
#                     delay loop. m0 row j is virtual node j's snapshot;
#                     the carried physical state is row N-1. w_in is the
#                     input mask, w_cp mixes the PREVIOUS tick's snapshots
#                     into per-node feedback (identity = the classic
#                     delay-line self-feedback), and params.a_cp is the
#                     feedback gain.
#   array_transient   Kanao et al. (arXiv:1905.07937): coupled-array
#                     dynamics, but each tick's reservoir state is the
#                     mean of m_x over the last `readout_window` RK
#                     substeps of the hold window (the transient), not the
#                     endpoint alone. readout_window=1 is bit-identical to
#                     coupled_array.
TOPOLOGIES = ("coupled_array", "time_multiplexed", "array_transient")


class SimSpec(NamedTuple):
    """Pure physics description of one reservoir (or an ensemble template).

    params may carry scalar leaves (one physical device) or (E, 1) ensemble
    leaves from `repro.core.ensemble.broadcast_params` (a parameter sweep);
    execution width is still the ExecPlan's call — scalar params broadcast
    into however many lanes the plan runs.
    """

    params: STOParams
    w_cp: jnp.ndarray  # (N, N) coupling topology (family: feedback mixing)
    w_in: jnp.ndarray  # (N, N_in) input topology (family: input mask)
    m0: jnp.ndarray  # (N, 3) canonical initial magnetization
    dt: float
    hold_steps: int  # integration steps per input sample
    tableau: str = "rk4"
    # Physics-family fields (appended with defaults so positional
    # construction of pre-family specs keeps meaning what it meant).
    topology: str = "coupled_array"  # one of TOPOLOGIES
    readout_window: int = 0  # array_transient: trailing substeps averaged

    @property
    def n(self) -> int:
        return int(self.m0.shape[0])

    @property
    def n_in(self) -> int:
        return int(self.w_in.shape[1])

    @property
    def dtype(self):
        return self.m0.dtype

    @classmethod
    def from_reservoir(cls, res, tableau: str = "rk4") -> "SimSpec":
        """Adopt a legacy `repro.core.reservoir.Reservoir`."""
        return cls(
            params=res.params,
            w_cp=res.w_cp,
            w_in=res.w_in,
            m0=res.m0,
            dt=res.dt,
            hold_steps=res.hold_steps,
            tableau=tableau,
        )

    def with_knobs(self, **knobs) -> "SimSpec":
        """A new SimSpec with named knobs applied — the validated write path
        for parameter search (`repro.tune`).

        Accepts any LANE_TUNABLE name (an STOParams field: current, a_cp,
        a_in, alpha, ...) as a scalar override of `params`, and any
        STRUCT_TUNABLE name (dt, hold_steps). Unknown names raise with the
        full valid list — a typo'd search space fails at construction, not
        as a silently-ignored knob. Lane overrides require scalar-leaved
        params (a sweep template); per-lane values ride sessions/plans, not
        the spec.
        """
        lane_kw = {}
        struct_kw = {}
        for name, value in knobs.items():
            if name in LANE_TUNABLE:
                lane_kw[name] = value
            elif name in STRUCT_TUNABLE:
                struct_kw[name] = value
            else:
                raise ValueError(
                    f"unknown spec knob {name!r}; lane-tunable: "
                    f"{LANE_TUNABLE}, structural: {STRUCT_TUNABLE}"
                )
        spec = self
        if lane_kw:
            leaf = jnp.asarray(self.params.gamma)
            if leaf.ndim != 0:
                raise ValueError(
                    "with_knobs lane overrides require scalar-leaved params; "
                    "this spec carries ensemble leaves — apply per-lane "
                    "values via broadcast_params / session params instead"
                )
            dt_ = self.dtype
            spec = spec._replace(
                params=self.params._replace(
                    **{k: jnp.asarray(v, dt_) for k, v in lane_kw.items()}
                )
            )
        if struct_kw:
            if "hold_steps" in struct_kw:
                hs = struct_kw["hold_steps"]
                if isinstance(hs, bool) or not isinstance(hs, int) or hs < 1:
                    raise ValueError(
                        f"hold_steps must be an int >= 1; got {hs!r}"
                    )
            if "dt" in struct_kw and not float(struct_kw["dt"]) > 0.0:
                raise ValueError(f"dt must be > 0; got {struct_kw['dt']!r}")
            spec = spec._replace(**struct_kw)
        return spec

    def to_reservoir(self):
        """Project back to the legacy Reservoir tuple (drops the tableau)."""
        from repro.core.reservoir import Reservoir

        if self.topology != "coupled_array":
            raise ValueError(
                "to_reservoir is lossy for physics families: the legacy "
                f"Reservoir tuple has no topology field (got {self.topology!r})"
            )
        return Reservoir(
            params=self.params,
            w_cp=self.w_cp,
            w_in=self.w_in,
            m0=self.m0,
            dt=self.dt,
            hold_steps=self.hold_steps,
        )


def validate_topology(spec: SimSpec) -> None:
    """Family invariants every consumer (compile_plan, engines) enforces.

    Raises ValueError on an unknown topology or a readout_window that does
    not fit the family: array_transient needs 1 <= readout_window <=
    hold_steps (the averaged transient tail), every other family requires
    the field left at 0 — a non-default window on a family that ignores it
    would silently hash/serve as if it mattered.
    """
    if spec.topology not in TOPOLOGIES:
        raise ValueError(
            f"unknown topology {spec.topology!r}; expected one of {TOPOLOGIES}"
        )
    w = spec.readout_window
    if isinstance(w, bool) or not isinstance(w, int):
        raise ValueError(f"readout_window must be an int; got {w!r}")
    if spec.topology == "array_transient":
        if not 1 <= w <= int(spec.hold_steps):
            raise ValueError(
                "array_transient requires 1 <= readout_window <= hold_steps"
                f" ({spec.hold_steps}); got {w}"
            )
    elif w != 0:
        raise ValueError(
            f"readout_window is an array_transient field; topology "
            f"{spec.topology!r} requires readout_window=0 (got {w})"
        )


def make_spec(
    n: int,
    n_in: int = 1,
    seed: int = 0,
    dt: float = constants.DT,
    hold_steps: int = 100,
    dtype=jnp.float32,
    params: Optional[STOParams] = None,
    tableau: str = "rk4",
    topology: str = "coupled_array",
    readout_window: int = 0,
) -> SimSpec:
    """Build a SimSpec with the paper's Table-1 defaults (cf. make_reservoir)."""
    if params is None:
        params = constants.default_params(dtype)
    w_cp = jnp.asarray(coupling.make_coupling_matrix(n, seed=seed), dtype=dtype)
    w_in = jnp.asarray(coupling.make_input_matrix(n, n_in, seed=seed + 1), dtype=dtype)
    m0 = constants.initial_magnetization(n, dtype=dtype)
    spec = SimSpec(
        params, w_cp, w_in, m0, dt, hold_steps, tableau,
        topology=topology, readout_window=readout_window,
    )
    validate_topology(spec)
    return spec


def make_time_multiplexed_spec(
    n_virtual: int,
    n_in: int = 1,
    seed: int = 0,
    dt: float = constants.DT,
    hold_steps: int = 10,
    dtype=jnp.float32,
    params: Optional[STOParams] = None,
    tableau: str = "rk4",
) -> SimSpec:
    """A Riou-style time-multiplexed single-oscillator reservoir.

    One physical oscillator; `n_virtual` virtual nodes, each holding the
    input for `hold_steps` RK substeps (hold_steps here is the VIRTUAL-NODE
    window theta, so one input sample occupies n_virtual * hold_steps
    substeps of physical time). w_in is a random binary ±1 input mask over
    virtual nodes (the paper's time-multiplexing mask); w_cp defaults to
    the identity — node j's drive feeds back from node j's snapshot one
    tick earlier, the classic delay-line loop — with params.a_cp the
    feedback gain. Rows of m0 are per-virtual-node snapshots; every backend
    carries the physical oscillator state as row n_virtual - 1.
    """
    if params is None:
        params = constants.default_params(dtype)
    rng = np.random.default_rng(seed)
    mask = rng.choice((-1.0, 1.0), size=(n_virtual, n_in))
    w_in = jnp.asarray(mask, dtype=dtype)
    w_cp = jnp.eye(n_virtual, dtype=dtype)
    m0 = constants.initial_magnetization(n_virtual, dtype=dtype)
    spec = SimSpec(
        params, w_cp, w_in, m0, dt, hold_steps, tableau,
        topology="time_multiplexed", readout_window=0,
    )
    validate_topology(spec)
    return spec


def make_array_transient_spec(
    n: int,
    readout_window: int,
    n_in: int = 1,
    seed: int = 0,
    dt: float = constants.DT,
    hold_steps: int = 100,
    dtype=jnp.float32,
    params: Optional[STOParams] = None,
    tableau: str = "rk4",
) -> SimSpec:
    """A Kanao-style array whose state is read from the transient window."""
    return make_spec(
        n, n_in=n_in, seed=seed, dt=dt, hold_steps=hold_steps, dtype=dtype,
        params=params, tableau=tableau, topology="array_transient",
        readout_window=readout_window,
    )
