"""SimSpec: WHAT to simulate — the pure physics of a coupled-STO reservoir.

A `SimSpec` is everything the paper's equations need and nothing the
hardware cares about: the LLG/STO parameter set, the coupling and input
topologies, the initial magnetization, the RK timestep/tableau, and the
hold window (integration steps per input sample). How that evolution is
executed — impl choice, padding, ensemble batching, sharding — lives in
`repro.api.plan.ExecPlan`; `repro.api.compile_plan(spec, plan)` marries the
two.

`SimSpec` subsumes `repro.core.reservoir.Reservoir` (same leading fields,
plus the tableau); `SimSpec.from_reservoir` / `.to_reservoir` convert
losslessly, so legacy call sites interoperate during the migration.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import constants, coupling
from repro.core.constants import STOParams

# Knob classification for `repro.tune` (and anything else sweeping specs).
#
# LANE_TUNABLE fields vary PER ENSEMBLE LANE of one CompiledSim: they are
# exactly the STOParams leaves, which every backend reads as (E, 1) columns
# — so E candidates with different values ride ONE dispatch (a_cp is the
# effective spectral radius: make_coupling_matrix normalizes W^cp to
# rho = 1, so the per-lane a_cp scale IS rho of the effective coupling).
#
# STRUCT_TUNABLE fields are STRUCTURAL: dt and hold_steps are static
# arguments of the jit'd workers (dt scales every RK stage, hold_steps is
# a scan length), so changing them means a different compiled simulator —
# searches over them group candidates per value (repro.tune compiles one
# engine per structural combination and sweeps lane knobs within each).
LANE_TUNABLE = STOParams._fields
STRUCT_TUNABLE = ("dt", "hold_steps")


class SimSpec(NamedTuple):
    """Pure physics description of one reservoir (or an ensemble template).

    params may carry scalar leaves (one physical device) or (E, 1) ensemble
    leaves from `repro.core.ensemble.broadcast_params` (a parameter sweep);
    execution width is still the ExecPlan's call — scalar params broadcast
    into however many lanes the plan runs.
    """

    params: STOParams
    w_cp: jnp.ndarray  # (N, N) coupling topology
    w_in: jnp.ndarray  # (N, N_in) input topology
    m0: jnp.ndarray  # (N, 3) canonical initial magnetization
    dt: float
    hold_steps: int  # integration steps per input sample
    tableau: str = "rk4"

    @property
    def n(self) -> int:
        return int(self.m0.shape[0])

    @property
    def n_in(self) -> int:
        return int(self.w_in.shape[1])

    @property
    def dtype(self):
        return self.m0.dtype

    @classmethod
    def from_reservoir(cls, res, tableau: str = "rk4") -> "SimSpec":
        """Adopt a legacy `repro.core.reservoir.Reservoir`."""
        return cls(
            params=res.params,
            w_cp=res.w_cp,
            w_in=res.w_in,
            m0=res.m0,
            dt=res.dt,
            hold_steps=res.hold_steps,
            tableau=tableau,
        )

    def with_knobs(self, **knobs) -> "SimSpec":
        """A new SimSpec with named knobs applied — the validated write path
        for parameter search (`repro.tune`).

        Accepts any LANE_TUNABLE name (an STOParams field: current, a_cp,
        a_in, alpha, ...) as a scalar override of `params`, and any
        STRUCT_TUNABLE name (dt, hold_steps). Unknown names raise with the
        full valid list — a typo'd search space fails at construction, not
        as a silently-ignored knob. Lane overrides require scalar-leaved
        params (a sweep template); per-lane values ride sessions/plans, not
        the spec.
        """
        lane_kw = {}
        struct_kw = {}
        for name, value in knobs.items():
            if name in LANE_TUNABLE:
                lane_kw[name] = value
            elif name in STRUCT_TUNABLE:
                struct_kw[name] = value
            else:
                raise ValueError(
                    f"unknown spec knob {name!r}; lane-tunable: "
                    f"{LANE_TUNABLE}, structural: {STRUCT_TUNABLE}"
                )
        spec = self
        if lane_kw:
            leaf = jnp.asarray(self.params.gamma)
            if leaf.ndim != 0:
                raise ValueError(
                    "with_knobs lane overrides require scalar-leaved params; "
                    "this spec carries ensemble leaves — apply per-lane "
                    "values via broadcast_params / session params instead"
                )
            dt_ = self.dtype
            spec = spec._replace(
                params=self.params._replace(
                    **{k: jnp.asarray(v, dt_) for k, v in lane_kw.items()}
                )
            )
        if struct_kw:
            if "hold_steps" in struct_kw:
                hs = struct_kw["hold_steps"]
                if isinstance(hs, bool) or not isinstance(hs, int) or hs < 1:
                    raise ValueError(
                        f"hold_steps must be an int >= 1; got {hs!r}"
                    )
            if "dt" in struct_kw and not float(struct_kw["dt"]) > 0.0:
                raise ValueError(f"dt must be > 0; got {struct_kw['dt']!r}")
            spec = spec._replace(**struct_kw)
        return spec

    def to_reservoir(self):
        """Project back to the legacy Reservoir tuple (drops the tableau)."""
        from repro.core.reservoir import Reservoir

        return Reservoir(
            params=self.params,
            w_cp=self.w_cp,
            w_in=self.w_in,
            m0=self.m0,
            dt=self.dt,
            hold_steps=self.hold_steps,
        )


def make_spec(
    n: int,
    n_in: int = 1,
    seed: int = 0,
    dt: float = constants.DT,
    hold_steps: int = 100,
    dtype=jnp.float32,
    params: Optional[STOParams] = None,
    tableau: str = "rk4",
) -> SimSpec:
    """Build a SimSpec with the paper's Table-1 defaults (cf. make_reservoir)."""
    if params is None:
        params = constants.default_params(dtype)
    w_cp = jnp.asarray(coupling.make_coupling_matrix(n, seed=seed), dtype=dtype)
    w_in = jnp.asarray(coupling.make_input_matrix(n, n_in, seed=seed + 1), dtype=dtype)
    m0 = constants.initial_magnetization(n, dtype=dtype)
    return SimSpec(params, w_cp, w_in, m0, dt, hold_steps, tableau)
