"""SimSpec: WHAT to simulate — the pure physics of a coupled-STO reservoir.

A `SimSpec` is everything the paper's equations need and nothing the
hardware cares about: the LLG/STO parameter set, the coupling and input
topologies, the initial magnetization, the RK timestep/tableau, and the
hold window (integration steps per input sample). How that evolution is
executed — impl choice, padding, ensemble batching, sharding — lives in
`repro.api.plan.ExecPlan`; `repro.api.compile_plan(spec, plan)` marries the
two.

`SimSpec` subsumes `repro.core.reservoir.Reservoir` (same leading fields,
plus the tableau); `SimSpec.from_reservoir` / `.to_reservoir` convert
losslessly, so legacy call sites interoperate during the migration.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import constants, coupling
from repro.core.constants import STOParams


class SimSpec(NamedTuple):
    """Pure physics description of one reservoir (or an ensemble template).

    params may carry scalar leaves (one physical device) or (E, 1) ensemble
    leaves from `repro.core.ensemble.broadcast_params` (a parameter sweep);
    execution width is still the ExecPlan's call — scalar params broadcast
    into however many lanes the plan runs.
    """

    params: STOParams
    w_cp: jnp.ndarray  # (N, N) coupling topology
    w_in: jnp.ndarray  # (N, N_in) input topology
    m0: jnp.ndarray  # (N, 3) canonical initial magnetization
    dt: float
    hold_steps: int  # integration steps per input sample
    tableau: str = "rk4"

    @property
    def n(self) -> int:
        return int(self.m0.shape[0])

    @property
    def n_in(self) -> int:
        return int(self.w_in.shape[1])

    @property
    def dtype(self):
        return self.m0.dtype

    @classmethod
    def from_reservoir(cls, res, tableau: str = "rk4") -> "SimSpec":
        """Adopt a legacy `repro.core.reservoir.Reservoir`."""
        return cls(
            params=res.params,
            w_cp=res.w_cp,
            w_in=res.w_in,
            m0=res.m0,
            dt=res.dt,
            hold_steps=res.hold_steps,
            tableau=tableau,
        )

    def to_reservoir(self):
        """Project back to the legacy Reservoir tuple (drops the tableau)."""
        from repro.core.reservoir import Reservoir

        return Reservoir(
            params=self.params,
            w_cp=self.w_cp,
            w_in=self.w_in,
            m0=self.m0,
            dt=self.dt,
            hold_steps=self.hold_steps,
        )


def make_spec(
    n: int,
    n_in: int = 1,
    seed: int = 0,
    dt: float = constants.DT,
    hold_steps: int = 100,
    dtype=jnp.float32,
    params: Optional[STOParams] = None,
    tableau: str = "rk4",
) -> SimSpec:
    """Build a SimSpec with the paper's Table-1 defaults (cf. make_reservoir)."""
    if params is None:
        params = constants.default_params(dtype)
    w_cp = jnp.asarray(coupling.make_coupling_matrix(n, seed=seed), dtype=dtype)
    w_in = jnp.asarray(coupling.make_input_matrix(n, n_in, seed=seed + 1), dtype=dtype)
    m0 = constants.initial_magnetization(n, dtype=dtype)
    return SimSpec(params, w_cp, w_in, m0, dt, hold_steps, tableau)
