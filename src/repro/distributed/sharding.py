"""Sharding rules: param/cache/batch pytrees -> NamedShardings.

Megatron-style tensor parallelism with divisibility-aware fallbacks:

  embed / lm_head           vocab dim         -> model
  attention wq/wk/wv        out (heads*hd)    -> model  (column parallel)
  attention wo              in  (heads*hd)    -> model  (row parallel)
  mlp w_in/w_gate           out (d_ff)        -> model
  mlp w_out                 in  (d_ff)        -> model
  MoE experts (E, d, f)     E -> model if E % |model| == 0 else f -> model
  mamba in/out_proj         d_inner           -> model
  xlstm projections         d_inner           -> model
  biases / norms / small    replicated

Batch dims shard over ("pod", "data") for training and ("data",) or
configured axes for serving. Any dim not divisible by its axis size falls
back to replication (never fails to produce a valid sharding) — dry-run
coherence across all 10 archs relies on this.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Activation sharding constraints (enabled by the launcher/dry-run; model
# code calls constrain(x, BATCH, None, MODEL) unconditionally and it is a
# no-op unless a mesh was registered).
# ---------------------------------------------------------------------------

BATCH = "__batch__"  # placeholder resolved to ("pod","data") / ("data",)
MODEL = "__model__"

_ACTIVE_MESH: Optional[Mesh] = None


def kv_seq_mode() -> str:
    """KV-cache layout policy (§Perf B):
      "0"    heads/head_dim sharding (the naive baseline in §Roofline)
      "1"    force sequence sharding (flash-decode layout)
      "auto" (default) sequence sharding ONLY when kv_heads doesn't divide
             the model axis — measured per-cell in EXPERIMENTS.md §Perf:
             10-17.6x where heads don't divide, ~0.9x where they do."""
    import os

    return os.environ.get("REPRO_KV_SEQ_SHARD", "auto")


def want_kv_seq_shard(kv_heads: int, mesh: Optional[Mesh] = None) -> bool:
    mode = kv_seq_mode()
    if mode == "1":
        return True
    if mode == "0":
        return False
    mesh = mesh or _ACTIVE_MESH
    if mesh is None or "model" not in mesh.shape:
        return False
    # MLA latent caches pass kv_heads=0: always prefer seq sharding there
    return kv_heads == 0 or kv_heads % mesh.shape["model"] != 0


def enable_constraints(mesh: Optional[Mesh]):
    """Register the mesh used to resolve activation sharding constraints.
    Pass None to disable (single-device tests)."""
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def constrain(x, *spec):
    """with_sharding_constraint that (a) is inert without a registered mesh,
    (b) resolves BATCH/MODEL placeholders, (c) drops axes that don't divide."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    ba = _batch_axes(mesh)
    resolved = []
    for dim, s in zip(x.shape, spec):
        if s == BATCH:
            s = ba if len(ba) > 1 else (ba[0] if ba else None)
        elif s == MODEL:
            s = "model" if "model" in mesh.shape else None
        if s is not None and dim % _axis_size(mesh, s if isinstance(s, tuple) else (s,)) != 0:
            s = None
        resolved.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _ok(dim: int, mesh: Mesh, axes) -> bool:
    return dim % _axis_size(mesh, axes) == 0


# (path regex, candidate specs tried in order; first divisible wins)
# spec entries: tuple of per-dim axis assignments
_PARAM_RULES: Tuple[Tuple[str, Tuple[Tuple, ...]], ...] = (
    # embeddings: shard vocab; fall back to d_model
    (r"embed/embed$", ((("model",), None), (None, ("model",)))),
    (r"lm_head/kernel$", ((None, ("model",)),)),  # (d, vocab)
    (r"dec_pos$", ((None, None),)),
    # attention projections
    (r"(mixer|cross)/wq/kernel$", ((None, ("model",)),)),
    (r"(mixer|cross)/wk/kernel$", ((None, ("model",)),)),
    (r"(mixer|cross)/wv/kernel$", ((None, ("model",)),)),
    (r"(mixer|cross)/wo/kernel$", ((("model",), None),)),
    # MLA
    (r"mixer/wkv_a/kernel$", ((None, None),)),  # tiny latent proj: replicate
    (r"mixer/w_uk$", ((None, ("model",), None),)),  # (r, H, dn): shard heads
    (r"mixer/w_uv$", ((None, ("model",), None),)),
    # MoE: experts first, then expert-ff fallback
    (r"mlp/(w_gate|w_in)$", ((("model",), None, None), (None, None, ("model",)))),
    (r"mlp/w_out$", ((("model",), None, None), (None, ("model",), None))),
    (r"mlp/router/kernel$", ((None, None),)),
    (r"mlp/shared/(w_gate|w_in)/kernel$", ((None, ("model",)),)),
    (r"mlp/shared/w_out/kernel$", ((("model",), None),)),
    # dense MLP
    (r"mlp/(w_gate|w_in)/kernel$", ((None, ("model",)),)),
    (r"mlp/w_out/kernel$", ((("model",), None),)),
    # mamba
    (r"mixer/in_proj/kernel$", ((None, ("model",)),)),
    (r"mixer/out_proj/kernel$", ((("model",), None),)),
    (r"mixer/(conv_w|conv_b)$", ((None, ("model",)), (("model",),))),
    (r"mixer/x_proj/kernel$", ((("model",), None),)),
    (r"mixer/dt_proj/kernel$", ((None, ("model",)),)),
    (r"mixer/dt_proj/bias$", ((("model",),),)),
    (r"mixer/a_log$", ((("model",), None),)),
    (r"mixer/d_skip$", ((("model",),),)),
    # xlstm
    (r"mixer/up_proj/kernel$", ((None, ("model",)),)),
    (r"mixer/down_proj/kernel$", ((("model",), None),)),
    (r"mixer/(wq|wk|wv)/kernel$", ((None, ("model",)),)),
    (r"mixer/w_if/kernel$", ((None, None),)),
    (r"mixer/w_gates/kernel$", ((None, ("model",)),)),
    (r"mixer/r_gates$", ((None, ("model",), None, None),)),
    (r"mixer/b_gates$", ((None, None),)),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for_param(path: str, shape, mesh: Mesh, stacked: bool) -> P:
    """First matching rule whose axis sizes divide the dims; else replicate.

    stacked: leaf carries a leading num_periods axis (from scan stacking)."""
    ndims = len(shape)
    offset = 1 if stacked else 0
    for pat, candidates in _PARAM_RULES:
        if re.search(pat, path):
            for cand in candidates:
                if len(cand) != ndims - offset:
                    continue
                good = True
                for dim, axes in zip(shape[offset:], cand):
                    if axes is not None and not _ok(dim, mesh, axes):
                        good = False
                        break
                if good:
                    spec = (None,) * offset + tuple(
                        axes if axes is None else (axes[0] if len(axes) == 1 else axes)
                        for axes in cand
                    )
                    return P(*spec)
            break
    return P()  # replicate


def param_shardings(mesh: Mesh, params_or_specs, cfg=None):
    """NamedSharding pytree for a param tree (arrays or ShapeDtypeStructs)."""

    def assign(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("stack/") or "/stack/" in ps
        spec = _spec_for_param(ps, leaf.shape, mesh, stacked)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, params_or_specs)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_shardings(mesh: Mesh, batch_specs, seq_axis: Optional[str] = None):
    """Shard the leading batch dim over (pod, data); optionally the sequence
    dim over `seq_axis` (sequence parallelism for B=1 long-context)."""
    ba = _batch_axes(mesh)

    def assign(path, leaf):
        ps = _path_str(path)
        if "caches" in ps:
            return NamedSharding(mesh, cache_spec_for(ps, leaf, mesh))
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        bspec = ba if shape[0] % _axis_size(mesh, ba) == 0 else (
            ("data",) if shape[0] % _axis_size(mesh, ("data",)) == 0 else None
        )
        spec = [bspec] + [None] * (len(shape) - 1)
        if seq_axis and len(shape) >= 2 and shape[1] % _axis_size(mesh, (seq_axis,)) == 0:
            # only shard seq when batch is NOT absorbing that axis
            if bspec is None or seq_axis not in (bspec if isinstance(bspec, tuple) else (bspec,)):
                spec[1] = seq_axis
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(assign, batch_specs)


def cache_spec_for(path: str, leaf, mesh: Mesh) -> P:
    """KV-cache sharding: batch -> data(+pod), heads/head_dim -> model.

    Layouts: attention k/v (B,S,KVH,HD) [stacked: +lead]; MLA c_kv (B,S,r);
    mamba h (B,di,ds); conv_tail (B,K-1,di); xlstm c (B,H,dh,dh).
    """
    ba = _batch_axes(mesh)
    shape = leaf.shape
    stacked = "/stack/" in path or path.startswith("stack/")
    off = 1 if stacked else 0
    dims = shape[off:]
    spec = [None] * off + [None] * len(dims)

    # batch dim
    if dims and dims[0] % _axis_size(mesh, ba) == 0:
        spec[off] = ba if len(ba) > 1 else ba[0]
    elif dims and dims[0] % _axis_size(mesh, ("data",)) == 0:
        spec[off] = "data"

    def try_model(i):
        if dims[i] % _axis_size(mesh, ("model",)) == 0:
            spec[off + i] = "model"
            return True
        return False

    if re.search(r"/(k|v)$", path) and len(dims) == 4:
        # (B,S,KVH,HD). Layouts (§Perf B):
        #   heads -> model (fall back to head_dim), or
        #   sequence -> model (flash-decode style; decode attention reduces
        #   partial softmax stats instead of all-gathering the cache).
        # "auto" picks seq exactly when kv_heads doesn't divide the axis.
        if want_kv_seq_shard(dims[2], mesh):
            if try_model(1):
                return P(*spec)
        if not try_model(2):
            try_model(3)
    elif re.search(r"/(c_kv|k_rope)$", path) and len(dims) == 3:
        # MLA latent cache (B, S, r): seq-sharded layout (auto: always — the
        # latent has no head structure to shard cleanly; 7.8x in §Perf B)
        if want_kv_seq_shard(0, mesh):
            if try_model(1):
                return P(*spec)
        try_model(2)
    elif re.search(r"/(h|conv_tail)$", path) and len(dims) == 3:
        try_model(1) if re.search(r"/h$", path) else try_model(2)
    elif re.search(r"/c$", path) and len(dims) == 4:
        try_model(1)
    elif re.search(r"/(n|m)$", path) and len(dims) >= 2:
        try_model(1)
    return P(*spec)


# ---------------------------------------------------------------------------
# Reservoir ensemble shardings (consumed by repro.api's sharded plans)
# ---------------------------------------------------------------------------


def reservoir_specs(
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
):
    """PartitionSpecs for the coupled-STO ensemble state.

    The layout every sharded reservoir path in this repo uses: the ensemble
    axis E spans `ensemble_axes` (data/pod parallelism — independent
    reservoirs), the oscillator axis N spans `model_axis` (W^cp row-sharded;
    each RK stage all-gathers the m^x slice). Keys:

      params  STOParams leaves (E, 1)
      w       coupling matrix (N, N), row-sharded
      w_in    input matrix (N, N_in), row-sharded like w
      m       magnetization (E, N, 3)
      u       shared input series (T, N_in), replicated
      u_e     per-lane input (T, E, N_in)
      u_tick  one tick's per-lane input rows (E, N_in)
      lane    per-lane vectors (E,) — masks, gains
      lane_block  per-tick per-lane mask block (K, E) — chunked serving
      states  collected node states (T, E, N)
      states_tick  one tick's states plane (E, N)
      learn_p  per-lane RLS inverse-Gram (E, S, S) — lane-sharded, the
               (S, S) = (N+1, N+1) feature block replicated (the update
               consumes the all-gathered feature vector)
      learn_w  per-lane readout weights (E, S, n_out), sharded like learn_p
      y_block  per-tick per-lane targets / predictions (K, E, n_out)
    """
    ens = tuple(ensemble_axes)
    return {
        "params": P(ens),
        "w": P(model_axis, None),
        "w_in": P(model_axis, None),
        "m": P(ens, model_axis, None),
        "u": P(None, None),
        "u_e": P(None, ens, None),
        "u_tick": P(ens, None),
        "lane": P(ens),
        "lane_block": P(None, ens),
        "states": P(None, ens, model_axis),
        "states_tick": P(ens, model_axis),
        "learn_p": P(ens, None, None),
        "learn_w": P(ens, None, None),
        "y_block": P(None, ens, None),
    }


def logical_summary(mesh: Mesh, params) -> str:
    """Debug helper: param path -> spec table."""
    rows = []

    def walk(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("stack/") or "/stack/" in ps
        spec = _spec_for_param(ps, leaf.shape, mesh, stacked)
        rows.append(f"{ps:60s} {str(leaf.shape):24s} {spec}")
        return leaf

    jax.tree_util.tree_map_with_path(walk, params)
    return "\n".join(rows)
