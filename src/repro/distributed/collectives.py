"""Explicit collective helpers (shard_map level).

pjit/GSPMD inserts collectives implicitly; these helpers exist for the
cases where the implicit form can't express the optimization:

  - compressed_psum: int8-quantized gradient all-reduce (wire bytes / 4 vs
    f32) with per-tensor scales — the distributed-optimization trick the
    implicit DP all-reduce can't do (XLA would fuse away a quant->dequant).
  - ring_allgather: collective_permute ring, the building block used by the
    sharded ensemble integrator when profiling showed all_gather latency
    (kept for the §Perf experiments).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import SHARD_MAP_CHECK_KW as _SHARD_MAP_CHECK_KW
from repro.core.compat import shard_map


def compressed_psum(x: jnp.ndarray, axis_name: str, num_devices: int):
    """int8 all-reduce with per-tensor scale (inside shard_map).

    Each device quantizes its shard contribution to int8; the psum runs over
    int32 accumulators (exact for <= 2^23 / 127 devices); dequantized with
    the max of the per-device scales (psum'd alongside, f32, negligible).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)  # shared scale => exact int sum
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    s = jax.lax.psum(q, axis_name)
    return (s.astype(jnp.float32) * scale) / num_devices


def dp_mean_grads_compressed(mesh: Mesh, grads, axis_name: str = "data"):
    """Data-parallel gradient mean with int8 wire format via shard_map.

    grads: pytree of per-host gradient shards laid out batch-style
    (replicated over `axis_name` logically; here each device holds its local
    sum). Returns the dequantized mean, replicated.
    """
    n = mesh.shape[axis_name]

    def local(g):
        return jax.tree.map(
            lambda t: compressed_psum(t, axis_name, n), g
        )

    specs = jax.tree.map(lambda _: P(), grads)
    fn = shard_map(
        local, mesh=mesh, in_specs=(specs,), out_specs=specs,
        **_SHARD_MAP_CHECK_KW,
    )
    return fn(grads)


def ring_allgather(x: jnp.ndarray, axis_name: str, num_devices: int):
    """All-gather along `axis_name` as a collective_permute ring — overlaps
    with compute chunk-by-chunk where a monolithic all-gather cannot."""
    def step(carry, _):
        buf, acc = carry
        nxt = jax.lax.ppermute(
            buf, axis_name,
            [(i, (i + 1) % num_devices) for i in range(num_devices)],
        )
        return (nxt, acc + [nxt]), None

    chunks = [x]
    buf = x
    for _ in range(num_devices - 1):
        buf = jax.lax.ppermute(
            buf, axis_name,
            [(i, (i + 1) % num_devices) for i in range(num_devices)],
        )
        chunks.append(buf)
    # device i received chunks in order i, i-1, ...; rotate to global order
    idx = jax.lax.axis_index(axis_name)
    stacked = jnp.stack(chunks)  # (n, ...)
    order = (idx - jnp.arange(num_devices)) % num_devices
    return jnp.take(stacked, order, axis=0)
