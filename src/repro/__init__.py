"""repro: virtual reservoir acceleration on TPU (JAX + Pallas).

Public surface:
    repro.api         unified execution API: SimSpec x ExecPlan ->
                      compile_plan -> CompiledSim (drive / drive_batch /
                      integrate / tick)
    repro.core        the paper's coupled-STO reservoir physics
    repro.kernels     Pallas TPU kernels (+ interpret-mode oracles)
    repro.models      assigned-architecture zoo (build_model)
    repro.configs     arch registry (get_config / list_configs)
    repro.train       fault-tolerant training loop + checkpoints
    repro.launch      mesh / dryrun / roofline / train / serve entrypoints
"""

__version__ = "0.1.0"
